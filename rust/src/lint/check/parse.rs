//! Phase-1 item parser for `pallas-check`: walks one file's token
//! stream (from the tier-1 [`lexer`](crate::lint::lexer)) and collects
//! item definitions per module — fn signatures, struct fields, enum
//! variants, trait method sets, impl blocks, const/static/type items,
//! and `use` declarations (including renames, brace groups and globs).
//!
//! The parser is deliberately shallow: it tracks bracket depth and a
//! handful of keywords, never types. Anything it cannot classify it
//! skips without error — the resolver treats the enclosing module as
//! *open* (macro-tainted) rather than guessing, so parse blind spots
//! become false negatives, never false positives.

use std::collections::{BTreeMap, BTreeSet};

use crate::lint::lexer::{LineComment, Tok, TokKind};

/// Keywords that can never begin a value/type path in expression
/// position. `crate` and `super` are absent on purpose: they do start
/// paths.
pub(crate) const KEYWORDS_NOT_PATH_START: [&str; 36] = [
    "fn", "let", "if", "else", "match", "while", "for", "loop", "return", "break", "continue",
    "impl", "trait", "struct", "enum", "mod", "use", "pub", "const", "static", "type", "where",
    "unsafe", "async", "move", "ref", "mut", "dyn", "as", "in", "extern", "await", "box",
    "macro_rules", "true", "false",
];

/// Shape of a struct or enum-variant body.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub(crate) enum AdtKind {
    Unit,
    Tuple,
    Named,
}

/// How a method binds `self` (only presence matters to the rules; the
/// flavor is kept for diagnostics-by-eye while debugging fixtures).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub(crate) enum SelfKind {
    Value,
    Ref,
    RefMut,
}

#[derive(Debug, Clone)]
pub(crate) struct FnDef {
    pub name: String,
    /// Parameter count INCLUDING `self` when present.
    pub arity: usize,
    pub self_kind: Option<SelfKind>,
    pub line: u32,
    /// `""` | `"pub"` | `"pub(crate)"` | …
    pub vis: String,
    pub cfg: bool,
    pub generics: BTreeSet<String>,
    /// Token range of the body (or `(end, end)` for a bodyless decl).
    pub body: (usize, usize),
    pub has_body: bool,
}

#[derive(Debug, Clone)]
pub(crate) struct StructDef {
    pub name: String,
    pub kind: AdtKind,
    pub fields: Vec<String>,
    pub tuple_arity: usize,
    pub line: u32,
    pub vis: String,
    pub cfg: bool,
    pub derives: BTreeSet<String>,
}

#[derive(Debug, Clone)]
pub(crate) struct VariantDef {
    pub name: String,
    pub kind: AdtKind,
    pub fields: Vec<String>,
    pub tuple_arity: usize,
}

#[derive(Debug, Clone)]
pub(crate) struct EnumDef {
    pub name: String,
    /// Declaration order preserved (exhaustiveness counts compare
    /// against it).
    pub variants: Vec<VariantDef>,
    pub line: u32,
    pub vis: String,
    pub cfg: bool,
    pub derives: BTreeSet<String>,
}

impl EnumDef {
    pub fn variant(&self, name: &str) -> Option<&VariantDef> {
        self.variants.iter().find(|v| v.name == name)
    }
}

#[derive(Debug, Clone)]
pub(crate) struct TraitDef {
    pub name: String,
    pub required: BTreeMap<String, FnDef>,
    pub provided: BTreeMap<String, FnDef>,
    /// Associated consts and types declared by the trait.
    pub assoc: BTreeSet<String>,
    pub line: u32,
    pub vis: String,
    pub cfg: bool,
}

#[derive(Debug, Clone)]
pub(crate) struct ImplDef {
    /// `None` when the impl target is not a plain path (tuples, refs).
    pub type_name: Option<String>,
    /// `None` for inherent impls; the trait's path segments otherwise.
    pub trait_path: Option<Vec<String>>,
    pub methods: BTreeMap<String, Vec<FnDef>>,
    /// Associated consts/types defined in the impl body.
    pub assoc: BTreeSet<String>,
    pub line: u32,
    pub cfg: bool,
    pub generics: BTreeSet<String>,
    /// Token range of the impl body.
    pub body: (usize, usize),
}

/// A `const`, `static` or `type` alias item (shape is identical for
/// the rules' purposes: a named, possibly-pub leaf).
#[derive(Debug, Clone)]
pub(crate) struct ConstDef {
    pub name: String,
    pub line: u32,
    pub vis: String,
    pub cfg: bool,
}

#[derive(Debug, Clone)]
pub(crate) struct UseDef {
    /// Local name bound by the import; `None` for globs.
    pub alias: Option<String>,
    /// Path segments (for globs: the module path before `::*`).
    pub path: Vec<String>,
    pub line: u32,
    pub is_glob: bool,
    pub cfg: bool,
}

#[derive(Debug, Clone)]
pub(crate) struct ModDecl {
    pub name: String,
    pub line: u32,
    pub cfg: bool,
}

/// Everything defined directly in one module.
#[derive(Debug, Default)]
pub(crate) struct ModItems {
    pub fns: BTreeMap<String, Vec<FnDef>>,
    pub structs: BTreeMap<String, Vec<StructDef>>,
    pub enums: BTreeMap<String, Vec<EnumDef>>,
    pub traits: BTreeMap<String, Vec<TraitDef>>,
    pub consts: BTreeMap<String, Vec<ConstDef>>,
    /// Type aliases.
    pub types: BTreeMap<String, Vec<ConstDef>>,
    pub uses: Vec<UseDef>,
    pub mod_decls: Vec<ModDecl>,
    /// Inline `mod x { … }` bodies; drained into child modules by the
    /// tree builder.
    pub inline_mods: BTreeMap<String, ModItems>,
    pub impls: Vec<ImplDef>,
    /// The module contains a macro definition or item-position macro
    /// invocation — it may define items this parser cannot see, so
    /// resolution failures inside it degrade to "unknown".
    pub macro_items: bool,
    /// Inline mod under `#[cfg(test)]` (dead-pub exempts it).
    pub test_only: bool,
    /// Token range this module covers in its file.
    pub tok_span: (usize, usize),
    /// Defining file, set by the tree builder.
    pub file: String,
}

/// Parse result for one file: the root [`ModItems`] (module path is
/// assigned later by the tree builder) plus the raw token/comment
/// streams the phase-2 walker re-reads.
#[derive(Debug)]
pub(crate) struct FileParse {
    pub toks: Vec<Tok>,
    pub comments: Vec<LineComment>,
    pub n_lines: u32,
    /// Taken (`Option::take`) by the tree builder when the file is
    /// attached to the module tree.
    pub root: Option<ModItems>,
    /// Token ranges of `macro_rules!` bodies — the walker skips them.
    pub macro_spans: Vec<(usize, usize)>,
}

pub(crate) fn ident_at(toks: &[Tok], i: usize) -> Option<&str> {
    match toks.get(i) {
        Some(t) if t.kind == TokKind::Ident => Some(&t.text),
        _ => None,
    }
}

pub(crate) fn punct_at(toks: &[Tok], i: usize) -> Option<&str> {
    match toks.get(i) {
        Some(t) if t.kind == TokKind::Punct => Some(&t.text),
        _ => None,
    }
}

pub(crate) fn is_punct(toks: &[Tok], i: usize, c: char) -> bool {
    punct_at(toks, i).is_some_and(|p| p.len() == 1 && p.as_bytes()[0] == c as u8)
}

/// Index of the token AFTER the bracket group opening at `i`.
pub(crate) fn match_close(toks: &[Tok], mut i: usize, open: char, close: char) -> usize {
    let mut depth = 0i32;
    let n = toks.len();
    while i < n {
        if is_punct(toks, i, open) {
            depth += 1;
        } else if is_punct(toks, i, close) {
            depth -= 1;
            if depth == 0 {
                return i + 1;
            }
        }
        i += 1;
    }
    n
}

/// `i` at `#`; returns (index after the attribute, idents inside it).
pub(crate) fn skip_attr(toks: &[Tok], i: usize) -> (usize, Vec<String>) {
    let mut idents = Vec::new();
    let mut j = i + 1; // at `[`
    let mut depth = 0i32;
    let n = toks.len();
    while j < n {
        match toks[j].kind {
            TokKind::Punct => match toks[j].text.as_str() {
                "[" | "(" => depth += 1,
                "]" | ")" => {
                    depth -= 1;
                    if depth == 0 {
                        return (j + 1, idents);
                    }
                }
                _ => {}
            },
            TokKind::Ident => idents.push(toks[j].text.clone()),
            _ => {}
        }
        j += 1;
    }
    (n, idents)
}

/// Parse a fn parameter list between `(` at `lo` and its `)` at
/// `hi - 1`. Returns (arity including self, self kind).
fn parse_params(toks: &[Tok], lo: usize, hi: usize) -> (usize, Option<SelfKind>) {
    let i = lo + 1;
    let end = hi.saturating_sub(1);
    if i >= end {
        return (0, None);
    }
    // Split on top-level commas, tracking (), [], {} and <> depth.
    let mut entries: Vec<(usize, usize)> = Vec::new();
    let mut depth_par = 0i32;
    let mut depth_ang = 0i32;
    let mut start = i;
    let mut j = i;
    let mut prev: Option<&str> = None;
    while j < end {
        let t = &toks[j];
        if t.kind == TokKind::Punct {
            match t.text.as_str() {
                "(" | "[" | "{" => depth_par += 1,
                ")" | "]" | "}" => depth_par -= 1,
                "<" if depth_par == 0 => depth_ang += 1,
                ">" if depth_par == 0 && prev != Some("-") => {
                    if depth_ang > 0 {
                        depth_ang -= 1;
                    }
                }
                "," if depth_par == 0 && depth_ang == 0 => {
                    entries.push((start, j));
                    start = j + 1;
                }
                _ => {}
            }
            prev = Some(&t.text);
        } else {
            prev = None;
        }
        j += 1;
    }
    if start < end {
        entries.push((start, end));
    }
    if entries.is_empty() {
        return (0, None);
    }
    // Self kind from the first entry: [&] [lifetime] [mut] self.
    let (a, b) = entries[0];
    let mut k = a;
    let mut is_ref = false;
    if k < b && is_punct(toks, k, '&') {
        is_ref = true;
        k += 1;
        if k < b && toks[k].kind == TokKind::Lifetime {
            k += 1;
        }
    }
    let mut is_mut = false;
    if k < b && ident_at(toks, k) == Some("mut") {
        is_mut = true;
        k += 1;
    }
    let mut self_kind = None;
    if k < b && ident_at(toks, k) == Some("self") {
        // Must not be `self::x` (a type path in an unusual spot).
        let is_path = k + 2 < b && is_punct(toks, k + 1, ':') && is_punct(toks, k + 2, ':');
        if !is_path {
            self_kind = Some(if is_ref {
                if is_mut {
                    SelfKind::RefMut
                } else {
                    SelfKind::Ref
                }
            } else {
                SelfKind::Value
            });
        }
    }
    (entries.len(), self_kind)
}

/// `i` at `<`; collect top-level generic parameter names.
/// Returns (names, index after the closing `>`).
pub(crate) fn parse_generics(toks: &[Tok], mut i: usize) -> (BTreeSet<String>, usize) {
    let mut names = BTreeSet::new();
    let mut depth = 0i32;
    let n = toks.len();
    let mut expecting = true; // at a parameter-name position
    let mut prev: Option<&str> = None;
    while i < n {
        let t = &toks[i];
        if t.kind == TokKind::Punct {
            match t.text.as_str() {
                "<" => depth += 1,
                ">" if prev != Some("-") => {
                    depth -= 1;
                    if depth == 0 {
                        return (names, i + 1);
                    }
                }
                "," if depth == 1 => expecting = true,
                ":" if depth == 1 => expecting = false,
                _ => {}
            }
            prev = Some(&t.text);
        } else {
            if t.kind == TokKind::Ident && depth == 1 && expecting && t.text != "const" {
                names.insert(t.text.clone());
                expecting = false;
            }
            prev = None;
        }
        i += 1;
    }
    (names, n)
}

struct Parser<'a> {
    toks: &'a [Tok],
    macro_spans: Vec<(usize, usize)>,
}

/// Parse one file's tokens into a [`FileParse`].
pub(crate) fn parse_file(toks: Vec<Tok>, comments: Vec<LineComment>, n_lines: u32) -> FileParse {
    let mut root = ModItems { tok_span: (0, toks.len()), ..ModItems::default() };
    let mut p = Parser { toks: &toks, macro_spans: Vec::new() };
    p.parse_items(0, toks.len(), &mut root);
    let macro_spans = p.macro_spans;
    FileParse { toks, comments, n_lines, root: Some(root), macro_spans }
}

impl<'a> Parser<'a> {
    #[allow(clippy::too_many_lines)]
    fn parse_items(&mut self, lo: usize, hi: usize, module: &mut ModItems) {
        let toks = self.toks;
        let mut i = lo;
        let mut vis = String::new();
        let mut cfg = false;
        let mut cfg_test = false;
        let mut derives: BTreeSet<String> = BTreeSet::new();

        macro_rules! reset_mods {
            () => {{
                vis.clear();
                cfg = false;
                cfg_test = false;
                derives.clear();
            }};
        }

        while i < hi {
            let t = &toks[i];
            if t.kind == TokKind::Punct && t.text == "#" {
                if is_punct(toks, i + 1, '[') && i + 1 < hi {
                    let (j, idents) = skip_attr(toks, i);
                    let has = |s: &str| idents.iter().any(|x| x == s);
                    if has("cfg") || has("cfg_attr") {
                        cfg = true;
                        if has("test") && !has("not") {
                            cfg_test = true;
                        }
                    }
                    if idents.first().map(String::as_str) == Some("derive") {
                        derives.extend(idents.iter().skip(1).cloned());
                    }
                    i = j;
                    continue;
                }
                i += 1;
                continue;
            }
            if t.kind != TokKind::Ident {
                i += 1;
                continue;
            }
            match t.text.as_str() {
                "pub" => {
                    vis = "pub".to_string();
                    i += 1;
                    // pub(crate) / pub(super) / pub(in …)
                    if i < hi && is_punct(toks, i, '(') {
                        let j = match_close(toks, i, '(', ')');
                        let inner: String = toks[i..j.min(hi)]
                            .iter()
                            .filter(|x| x.kind == TokKind::Ident)
                            .map(|x| x.text.as_str())
                            .collect();
                        vis = format!("pub({})", if inner.is_empty() { "?" } else { &inner });
                        i = j;
                    }
                }
                "unsafe" | "async" | "extern" | "default" => {
                    let was_extern = t.text == "extern";
                    i += 1;
                    if was_extern && i < hi && toks[i].kind == TokKind::Str {
                        i += 1;
                    }
                }
                "macro_rules" => {
                    // macro_rules ! name { … } — record and skip the body.
                    let mut j = i + 1;
                    if j < hi && is_punct(toks, j, '!') {
                        j += 1;
                    }
                    if j < hi && toks[j].kind == TokKind::Ident {
                        module.macro_items = true; // may be invoked to make items
                        j += 1;
                    }
                    while j < hi && !matches!(punct_at(toks, j), Some("{" | "(" | "[")) {
                        j += 1;
                    }
                    if j < hi {
                        let (o, c) = match punct_at(toks, j) {
                            Some("(") => ('(', ')'),
                            Some("[") => ('[', ']'),
                            _ => ('{', '}'),
                        };
                        let body_lo = j;
                        j = match_close(toks, j, o, c);
                        self.macro_spans.push((body_lo, j));
                    }
                    i = j;
                    reset_mods!();
                }
                "mod" => {
                    if let Some(name) = ident_at(toks, i + 1).filter(|_| i + 1 < hi) {
                        let name = name.to_string();
                        let line = t.line;
                        let nxt = i + 2;
                        if nxt < hi && is_punct(toks, nxt, ';') {
                            module.mod_decls.push(ModDecl { name, line, cfg });
                            i = nxt + 1;
                        } else if nxt < hi && is_punct(toks, nxt, '{') {
                            let close = match_close(toks, nxt, '{', '}');
                            let mut inner = ModItems {
                                test_only: cfg_test || module.test_only,
                                tok_span: (nxt + 1, close.saturating_sub(1)),
                                ..ModItems::default()
                            };
                            self.parse_items(nxt + 1, close.saturating_sub(1), &mut inner);
                            module.inline_mods.insert(name, inner);
                            i = close;
                        } else {
                            i = nxt;
                        }
                        reset_mods!();
                        continue;
                    }
                    i += 1;
                }
                "use" => {
                    let mut j = i + 1;
                    while j < hi && !is_punct(toks, j, ';') {
                        j += 1;
                    }
                    self.parse_use(i + 1, j, module, t.line, cfg);
                    i = j + 1;
                    reset_mods!();
                }
                "fn" => {
                    let (fd, j) = self.parse_fn(i, hi, &vis, cfg);
                    if let Some(fd) = fd {
                        module.fns.entry(fd.name.clone()).or_default().push(fd);
                    }
                    i = j;
                    reset_mods!();
                }
                "struct" => {
                    let (sd, j) = self.parse_struct(i, hi, &vis, cfg, &derives);
                    if let Some(sd) = sd {
                        module.structs.entry(sd.name.clone()).or_default().push(sd);
                    }
                    i = j;
                    reset_mods!();
                }
                "enum" => {
                    let (ed, j) = self.parse_enum(i, hi, &vis, cfg, &derives);
                    if let Some(ed) = ed {
                        module.enums.entry(ed.name.clone()).or_default().push(ed);
                    }
                    i = j;
                    reset_mods!();
                }
                "trait" => {
                    let (td, j) = self.parse_trait(i, hi, &vis, cfg);
                    if let Some(td) = td {
                        module.traits.entry(td.name.clone()).or_default().push(td);
                    }
                    i = j;
                    reset_mods!();
                }
                "impl" => {
                    let (idef, j) = self.parse_impl(i, hi, cfg);
                    if let Some(idef) = idef {
                        module.impls.push(idef);
                    }
                    i = j;
                    reset_mods!();
                }
                w @ ("const" | "static") => {
                    let _ = w;
                    let mut j = i + 1;
                    if j < hi && ident_at(toks, j) == Some("mut") {
                        j += 1;
                    }
                    if let Some(name) = ident_at(toks, j).filter(|_| j < hi) {
                        if name != "_" {
                            module.consts.entry(name.to_string()).or_default().push(ConstDef {
                                name: name.to_string(),
                                line: toks[j].line,
                                vis: vis.clone(),
                                cfg,
                            });
                        }
                    }
                    // Skip to `;` at depth 0 (initializers nest brackets).
                    let mut depth = 0i32;
                    while j < hi {
                        if toks[j].kind == TokKind::Punct {
                            match toks[j].text.as_str() {
                                "(" | "[" | "{" => depth += 1,
                                ")" | "]" | "}" => depth -= 1,
                                ";" if depth == 0 => {
                                    j += 1;
                                    break;
                                }
                                _ => {}
                            }
                        }
                        j += 1;
                    }
                    i = j;
                    reset_mods!();
                }
                "type" => {
                    let mut j = i + 1;
                    if let Some(name) = ident_at(toks, j).filter(|_| j < hi) {
                        module.types.entry(name.to_string()).or_default().push(ConstDef {
                            name: name.to_string(),
                            line: toks[j].line,
                            vis: vis.clone(),
                            cfg,
                        });
                    }
                    while j < hi && !is_punct(toks, j, ';') {
                        j += 1;
                    }
                    i = j + 1;
                    reset_mods!();
                }
                w => {
                    // Item-position macro invocation: `name ! ( … ) ;` etc.
                    if i + 1 < hi
                        && is_punct(toks, i + 1, '!')
                        && !KEYWORDS_NOT_PATH_START.contains(&w)
                    {
                        module.macro_items = true;
                        let mut j = i + 2;
                        if j < hi {
                            if let Some(o @ ("{" | "(" | "[")) = punct_at(toks, j) {
                                let (o, c) = match o {
                                    "(" => ('(', ')'),
                                    "[" => ('[', ']'),
                                    _ => ('{', '}'),
                                };
                                j = match_close(toks, j, o, c);
                            }
                        }
                        i = j;
                        reset_mods!();
                        continue;
                    }
                    i += 1;
                    reset_mods!();
                }
            }
        }
    }

    /// `i` at `fn`. Returns (parsed def, index after the item).
    fn parse_fn(&self, i: usize, hi: usize, vis: &str, cfg: bool) -> (Option<FnDef>, usize) {
        let toks = self.toks;
        let mut j = i + 1;
        let Some(name) = ident_at(toks, j).filter(|_| j < hi) else {
            return (None, i + 1);
        };
        let name = name.to_string();
        let line = toks[j].line;
        j += 1;
        let mut generics = BTreeSet::new();
        if j < hi && is_punct(toks, j, '<') {
            let (g, nj) = parse_generics(toks, j);
            generics = g;
            j = nj;
        }
        if j >= hi || !is_punct(toks, j, '(') {
            return (None, j);
        }
        let close = match_close(toks, j, '(', ')');
        let (arity, self_kind) = parse_params(toks, j, close);
        j = close;
        // Skip return type / where clause to the body `{` or decl `;`.
        let mut depth = 0i32;
        let mut body_end = hi;
        let mut found = false;
        while j < hi {
            let t = &toks[j];
            if t.kind == TokKind::Punct {
                match t.text.as_str() {
                    "(" | "[" | "<" => depth += 1,
                    ")" | "]" | ">" => {
                        if depth > 0 {
                            depth -= 1;
                        }
                    }
                    ";" if depth == 0 => {
                        body_end = j + 1;
                        found = true;
                        break;
                    }
                    "{" if depth == 0 => {
                        body_end = match_close(toks, j, '{', '}');
                        found = true;
                        break;
                    }
                    _ => {}
                }
            }
            j += 1;
        }
        if !found {
            body_end = hi;
        }
        let has_body = j < hi && is_punct(toks, j, '{');
        let fd = FnDef {
            name,
            arity,
            self_kind,
            line,
            vis: vis.to_string(),
            cfg,
            generics,
            body: (j, body_end),
            has_body,
        };
        (Some(fd), body_end)
    }

    fn parse_struct(
        &self,
        i: usize,
        hi: usize,
        vis: &str,
        cfg: bool,
        derives: &BTreeSet<String>,
    ) -> (Option<StructDef>, usize) {
        let toks = self.toks;
        let mut j = i + 1;
        let Some(name) = ident_at(toks, j).filter(|_| j < hi) else {
            return (None, i + 1);
        };
        let mut s = StructDef {
            name: name.to_string(),
            kind: AdtKind::Unit,
            fields: Vec::new(),
            tuple_arity: 0,
            line: toks[j].line,
            vis: vis.to_string(),
            cfg,
            derives: derives.clone(),
        };
        j += 1;
        if j < hi && is_punct(toks, j, '<') {
            let (_, nj) = parse_generics(toks, j);
            j = nj;
        }
        while j < hi {
            if is_punct(toks, j, ';') {
                return (Some(s), j + 1); // unit struct
            }
            if is_punct(toks, j, '(') {
                let close = match_close(toks, j, '(', ')');
                s.kind = AdtKind::Tuple;
                let (arity, _) = parse_params(toks, j, close);
                s.tuple_arity = arity;
                j = close;
                while j < hi && !is_punct(toks, j, ';') {
                    j += 1;
                }
                return (Some(s), j + 1);
            }
            if is_punct(toks, j, '{') {
                let close = match_close(toks, j, '{', '}');
                s.kind = AdtKind::Named;
                s.fields = self.parse_named_fields(j + 1, close.saturating_sub(1));
                return (Some(s), close);
            }
            j += 1;
        }
        (Some(s), hi)
    }

    /// Field names inside a struct/variant body: idents at depth 0
    /// directly followed by a single `:` at entry start.
    fn parse_named_fields(&self, lo: usize, hi: usize) -> Vec<String> {
        let toks = self.toks;
        let mut fields = Vec::new();
        let mut depth = 0i32;
        let mut at_entry_start = true;
        let mut j = lo;
        while j < hi {
            let t = &toks[j];
            if t.kind == TokKind::Punct {
                match t.text.as_str() {
                    "#" if is_punct(toks, j + 1, '[') && j + 1 < hi => {
                        let (nj, _) = skip_attr(toks, j);
                        j = nj;
                        continue;
                    }
                    "(" | "[" | "{" | "<" => depth += 1,
                    ")" | "]" | "}" => depth -= 1,
                    ">" if depth > 0 => depth -= 1,
                    "," if depth == 0 => {
                        at_entry_start = true;
                        j += 1;
                        continue;
                    }
                    _ => {}
                }
            } else if t.kind == TokKind::Ident && depth == 0 && at_entry_start {
                if t.text == "pub" {
                    j += 1;
                    if j < hi && is_punct(toks, j, '(') {
                        j = match_close(toks, j, '(', ')');
                    }
                    continue;
                }
                // A field declaration is `name: Type` — the name is
                // directly followed by a colon.
                if j + 1 < hi && is_punct(toks, j + 1, ':') {
                    fields.push(t.text.clone());
                }
                at_entry_start = false;
            }
            j += 1;
        }
        fields
    }

    fn parse_enum(
        &self,
        i: usize,
        hi: usize,
        vis: &str,
        cfg: bool,
        derives: &BTreeSet<String>,
    ) -> (Option<EnumDef>, usize) {
        let toks = self.toks;
        let mut j = i + 1;
        let Some(name) = ident_at(toks, j).filter(|_| j < hi) else {
            return (None, i + 1);
        };
        let mut e = EnumDef {
            name: name.to_string(),
            variants: Vec::new(),
            line: toks[j].line,
            vis: vis.to_string(),
            cfg,
            derives: derives.clone(),
        };
        j += 1;
        if j < hi && is_punct(toks, j, '<') {
            let (_, nj) = parse_generics(toks, j);
            j = nj;
        }
        while j < hi && !is_punct(toks, j, '{') {
            if is_punct(toks, j, ';') {
                return (Some(e), j + 1);
            }
            j += 1;
        }
        if j >= hi {
            return (Some(e), hi);
        }
        let close = match_close(toks, j, '{', '}');
        // Variants: idents at depth 0 at entry start, optionally with
        // a `(…)` or `{…}` payload.
        let mut k = j + 1;
        let body_end = close.saturating_sub(1);
        let mut at_entry_start = true;
        let mut depth = 0i32;
        while k < body_end {
            let t = &toks[k];
            if t.kind == TokKind::Punct {
                match t.text.as_str() {
                    "#" if k + 1 < close && is_punct(toks, k + 1, '[') => {
                        let (nk, _) = skip_attr(toks, k);
                        k = nk;
                        continue;
                    }
                    "(" | "[" | "{" => depth += 1,
                    ")" | "]" | "}" => depth -= 1,
                    "," if depth == 0 => at_entry_start = true,
                    _ => {}
                }
                k += 1;
                continue;
            }
            if t.kind == TokKind::Ident && depth == 0 && at_entry_start {
                let mut v = VariantDef {
                    name: t.text.clone(),
                    kind: AdtKind::Unit,
                    fields: Vec::new(),
                    tuple_arity: 0,
                };
                let nxt = k + 1;
                if nxt < body_end && is_punct(toks, nxt, '(') {
                    let c2 = match_close(toks, nxt, '(', ')');
                    v.kind = AdtKind::Tuple;
                    let (arity, _) = parse_params(toks, nxt, c2);
                    v.tuple_arity = arity;
                    k = c2;
                } else if nxt < body_end && is_punct(toks, nxt, '{') {
                    let c2 = match_close(toks, nxt, '{', '}');
                    v.kind = AdtKind::Named;
                    v.fields = self.parse_named_fields(nxt + 1, c2.saturating_sub(1));
                    k = c2;
                } else {
                    // Unit (an explicit `= discriminant` is skipped by
                    // the surrounding depth/comma tracking).
                    k = nxt;
                }
                e.variants.push(v);
                at_entry_start = false;
                continue;
            }
            k += 1;
        }
        (Some(e), close)
    }

    fn parse_trait(&self, i: usize, hi: usize, vis: &str, cfg: bool) -> (Option<TraitDef>, usize) {
        let toks = self.toks;
        let mut j = i + 1;
        let Some(name) = ident_at(toks, j).filter(|_| j < hi) else {
            return (None, i + 1);
        };
        let mut tr = TraitDef {
            name: name.to_string(),
            required: BTreeMap::new(),
            provided: BTreeMap::new(),
            assoc: BTreeSet::new(),
            line: toks[j].line,
            vis: vis.to_string(),
            cfg,
        };
        j += 1;
        if j < hi && is_punct(toks, j, '<') {
            let (_, nj) = parse_generics(toks, j);
            j = nj;
        }
        while j < hi && !matches!(punct_at(toks, j), Some("{" | ";")) {
            j += 1;
        }
        if j >= hi || punct_at(toks, j) == Some(";") {
            return (Some(tr), j + 1);
        }
        let close = match_close(toks, j, '{', '}');
        let body_end = close.saturating_sub(1);
        let mut k = j + 1;
        while k < body_end {
            let t = &toks[k];
            if t.kind == TokKind::Punct && t.text == "#" && k + 1 < close && is_punct(toks, k + 1, '[')
            {
                let (nk, _) = skip_attr(toks, k);
                k = nk;
                continue;
            }
            if t.kind == TokKind::Ident && t.text == "fn" {
                let (fd, k2) = self.parse_fn(k, body_end, "", false);
                if let Some(fd) = fd {
                    if fd.has_body {
                        tr.provided.insert(fd.name.clone(), fd);
                    } else {
                        tr.required.insert(fd.name.clone(), fd);
                    }
                }
                k = k2;
                continue;
            }
            if t.kind == TokKind::Ident && (t.text == "const" || t.text == "type") {
                if let Some(a) = ident_at(toks, k + 1).filter(|_| k + 1 < close) {
                    tr.assoc.insert(a.to_string());
                }
                while k < body_end && !is_punct(toks, k, ';') {
                    k += 1;
                }
                k += 1;
                continue;
            }
            k += 1;
        }
        (Some(tr), close)
    }

    /// `i` at `impl`. Handles `impl<G> Type { … }` and
    /// `impl<G> Trait for Type { … }`.
    fn parse_impl(&self, i: usize, hi: usize, cfg: bool) -> (Option<ImplDef>, usize) {
        let toks = self.toks;
        let mut j = i + 1;
        let mut generics = BTreeSet::new();
        if j < hi && is_punct(toks, j, '<') {
            let (g, nj) = parse_generics(toks, j);
            generics = g;
            j = nj;
        }
        // Collect the pre-body path tokens up to `{` at depth 0. A
        // `None` entry marks a non-path construct (tuple type).
        let mut segs1: Vec<Option<String>> = Vec::new();
        let mut segs2: Vec<Option<String>> = Vec::new();
        let mut in_second = false;
        let mut saw_for = false;
        let mut depth = 0i32;
        let mut prev: Option<&str> = None;
        while j < hi {
            let t = &toks[j];
            if t.kind == TokKind::Punct {
                match t.text.as_str() {
                    "<" => depth += 1,
                    ">" if prev != Some("-") => {
                        if depth > 0 {
                            depth -= 1;
                        }
                    }
                    "{" if depth == 0 => break,
                    "(" if depth == 0 => {
                        j = match_close(toks, j, '(', ')');
                        if in_second {
                            segs2.push(None);
                        } else {
                            segs1.push(None);
                        }
                        prev = Some(")");
                        continue;
                    }
                    _ => {}
                }
                prev = Some(&t.text);
            } else if t.kind == TokKind::Ident && depth == 0 {
                prev = None;
                match t.text.as_str() {
                    "for" => {
                        saw_for = true;
                        in_second = true;
                        j += 1;
                        continue;
                    }
                    "where" => {
                        while j < hi && !is_punct(toks, j, '{') {
                            j += 1;
                        }
                        break;
                    }
                    "dyn" | "mut" | "const" => {}
                    w => {
                        if in_second {
                            segs2.push(Some(w.to_string()));
                        } else {
                            segs1.push(Some(w.to_string()));
                        }
                    }
                }
            } else {
                prev = None;
            }
            j += 1;
        }
        if j >= hi || !is_punct(toks, j, '{') {
            return (None, j + 1);
        }
        let close = match_close(toks, j, '{', '}');
        let non_path1 = segs1.iter().any(Option::is_none);
        let non_path2 = segs2.iter().any(Option::is_none);
        let (trait_path, type_segs): (Option<Vec<String>>, Vec<String>) = if saw_for {
            let tp: Vec<String> = segs1.iter().flatten().cloned().collect();
            (
                if tp.is_empty() { None } else { Some(tp) },
                segs2.iter().flatten().cloned().collect(),
            )
        } else {
            (None, segs1.iter().flatten().cloned().collect())
        };
        let mut type_name = type_segs.last().cloned();
        if non_path1 || non_path2 || (saw_for && segs2.is_empty()) || (!saw_for && segs1.is_empty())
        {
            type_name = None;
        }
        let mut idef = ImplDef {
            type_name,
            trait_path,
            methods: BTreeMap::new(),
            assoc: BTreeSet::new(),
            line: toks[i].line,
            cfg,
            generics,
            body: (j + 1, close.saturating_sub(1)),
        };
        // Parse methods + assoc items in the body.
        let body_end = close.saturating_sub(1);
        let mut k = j + 1;
        let mut vis = String::new();
        let mut mcfg = false;
        while k < body_end {
            let t = &toks[k];
            if t.kind == TokKind::Punct && t.text == "#" && k + 1 < close && is_punct(toks, k + 1, '[')
            {
                let (nk, idents) = skip_attr(toks, k);
                if idents.iter().any(|x| x == "cfg") {
                    mcfg = true;
                }
                k = nk;
                continue;
            }
            if t.kind == TokKind::Ident {
                match t.text.as_str() {
                    "pub" => {
                        vis = "pub".to_string();
                        k += 1;
                        if k < close && is_punct(toks, k, '(') {
                            k = match_close(toks, k, '(', ')');
                        }
                        continue;
                    }
                    "unsafe" | "async" | "default" | "extern" => {
                        k += 1;
                        continue;
                    }
                    "fn" => {
                        let (fd, k2) = self.parse_fn(k, body_end, &vis, mcfg);
                        if let Some(fd) = fd {
                            idef.methods.entry(fd.name.clone()).or_default().push(fd);
                        }
                        k = k2;
                        vis.clear();
                        mcfg = false;
                        continue;
                    }
                    "const" | "type" => {
                        if let Some(a) = ident_at(toks, k + 1).filter(|_| k + 1 < close) {
                            idef.assoc.insert(a.to_string());
                        }
                        let mut depth = 0i32;
                        while k < body_end {
                            if toks[k].kind == TokKind::Punct {
                                match toks[k].text.as_str() {
                                    "(" | "[" | "{" => depth += 1,
                                    ")" | "]" | "}" => depth -= 1,
                                    ";" if depth == 0 => {
                                        k += 1;
                                        break;
                                    }
                                    _ => {}
                                }
                            }
                            k += 1;
                        }
                        vis.clear();
                        mcfg = false;
                        continue;
                    }
                    _ => {}
                }
            }
            k += 1;
            vis.clear();
            mcfg = false;
        }
        (Some(idef), close)
    }

    /// Parse the tokens of one `use` declaration (between `use` and `;`).
    fn parse_use(&self, lo: usize, hi: usize, module: &mut ModItems, line: u32, cfg: bool) {
        let prefix: Vec<String> = Vec::new();
        self.parse_use_tree(lo, hi, module, line, cfg, &prefix);
    }

    /// Recursive `use`-tree descent:
    /// `path := seg (:: seg)* [:: {tree, …}] [:: *] [as alias]`.
    /// Returns the index after the parsed subtree.
    fn parse_use_tree(
        &self,
        mut j: usize,
        hi: usize,
        module: &mut ModItems,
        line: u32,
        cfg: bool,
        prefix: &[String],
    ) -> usize {
        let toks = self.toks;
        let mut segs: Vec<String> = prefix.to_vec();
        while j < hi {
            let t = &toks[j];
            if t.kind == TokKind::Ident {
                if t.text == "as" {
                    let aliasable = j + 1 < hi
                        && (toks[j + 1].kind == TokKind::Ident || toks[j + 1].text == "_");
                    if aliasable {
                        module.uses.push(UseDef {
                            alias: Some(toks[j + 1].text.clone()),
                            path: segs,
                            line,
                            is_glob: false,
                            cfg,
                        });
                        return j + 2;
                    }
                    return j + 1;
                }
                segs.push(t.text.clone());
                j += 1;
                continue;
            }
            if t.kind == TokKind::Punct {
                match t.text.as_str() {
                    ":" => {
                        j += 1;
                        continue;
                    }
                    "*" => {
                        module.uses.push(UseDef {
                            alias: None,
                            path: segs,
                            line,
                            is_glob: true,
                            cfg,
                        });
                        return j + 1;
                    }
                    "{" => {
                        let close = match_close(toks, j, '{', '}');
                        let inner_end = close.saturating_sub(1);
                        let mut k = j + 1;
                        while k < inner_end {
                            if is_punct(toks, k, ',') {
                                k += 1;
                                continue;
                            }
                            k = self.parse_use_tree(k, inner_end, module, line, cfg, &segs);
                            while k < inner_end && is_punct(toks, k, ',') {
                                k += 1;
                            }
                        }
                        return close;
                    }
                    _ => break,
                }
            }
            break;
        }
        if segs.len() > prefix.len() {
            // A `self` leaf inside a brace group imports the module itself.
            if segs.last().map(String::as_str) == Some("self") && segs.len() > 1 {
                let alias = segs[segs.len() - 2].clone();
                segs.pop();
                module.uses.push(UseDef { alias: Some(alias), path: segs, line, is_glob: false, cfg });
            } else {
                let alias = segs.last().cloned();
                module.uses.push(UseDef { alias, path: segs, line, is_glob: false, cfg });
            }
        }
        j
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lint::lexer;

    fn parse(src: &str) -> FileParse {
        let out = lexer::lex(src);
        parse_file(out.toks, out.comments, out.n_lines)
    }

    #[test]
    fn fn_signatures_and_self_kinds() {
        let fp = parse(
            "pub fn free(a: u32, b: &str) -> u32 { a }\n\
             struct S { x: u32 }\n\
             impl S {\n    fn m(&mut self, k: u32) {}\n    fn assoc() -> S { S { x: 0 } }\n}\n",
        );
        let root = fp.root.unwrap();
        let free = &root.fns["free"][0];
        assert_eq!(free.arity, 2);
        assert_eq!(free.vis, "pub");
        assert!(free.self_kind.is_none());
        let imp = &root.impls[0];
        assert_eq!(imp.type_name.as_deref(), Some("S"));
        assert_eq!(imp.methods["m"][0].arity, 2);
        assert_eq!(imp.methods["m"][0].self_kind, Some(SelfKind::RefMut));
        assert!(imp.methods["assoc"][0].self_kind.is_none());
    }

    #[test]
    fn struct_enum_shapes() {
        let fp = parse(
            "pub struct Named { pub a: u32, b: Vec<(u32, u32)> }\n\
             struct Tup(u32, String);\nstruct Unit;\n\
             enum E { A, B(u32, u32), C { x: f64 } }\n",
        );
        let root = fp.root.unwrap();
        let named = &root.structs["Named"][0];
        assert_eq!(named.kind, AdtKind::Named);
        assert_eq!(named.fields, vec!["a", "b"]);
        assert_eq!(root.structs["Tup"][0].tuple_arity, 2);
        assert_eq!(root.structs["Unit"][0].kind, AdtKind::Unit);
        let e = &root.enums["E"][0];
        assert_eq!(e.variants.len(), 3);
        assert_eq!(e.variant("B").unwrap().tuple_arity, 2);
        assert_eq!(e.variant("C").unwrap().fields, vec!["x"]);
    }

    #[test]
    fn use_trees_globs_and_renames() {
        let fp = parse(
            "use crate::sim::{Event, world::World as W};\nuse std::collections::*;\n\
             use super::config::{self, ExperimentConfig};\n",
        );
        let root = fp.root.unwrap();
        let aliases: Vec<_> =
            root.uses.iter().filter_map(|u| u.alias.as_deref()).collect();
        assert!(aliases.contains(&"Event"));
        assert!(aliases.contains(&"W"));
        assert!(aliases.contains(&"config"));
        assert!(aliases.contains(&"ExperimentConfig"));
        assert!(root.uses.iter().any(|u| u.is_glob && u.path == ["std", "collections"]));
        let w = root.uses.iter().find(|u| u.alias.as_deref() == Some("W")).unwrap();
        assert_eq!(w.path, ["crate", "sim", "world", "World"]);
    }

    #[test]
    fn trait_and_impl_bodies() {
        let fp = parse(
            "trait T {\n    fn req(&self, x: u32) -> u32;\n    fn prov(&self) -> u32 { 0 }\n    const K: u32;\n}\n\
             struct S;\nimpl T for S {\n    fn req(&self, x: u32) -> u32 { x }\n    const K: u32 = 1;\n}\n",
        );
        let root = fp.root.unwrap();
        let t = &root.traits["T"][0];
        assert!(t.required.contains_key("req"));
        assert!(t.provided.contains_key("prov"));
        assert!(t.assoc.contains("K"));
        let imp = &root.impls[0];
        assert_eq!(imp.trait_path.as_deref(), Some(&["T".to_string()][..]));
        assert!(imp.assoc.contains("K"));
    }

    #[test]
    fn inline_mods_and_macro_spans() {
        let fp = parse(
            "mod inner { pub fn f() {} }\n#[cfg(test)]\nmod tests { fn t() {} }\n\
             macro_rules! m { () => { fn ghost() {} }; }\n",
        );
        let root = fp.root.unwrap();
        assert!(root.inline_mods["inner"].fns.contains_key("f"));
        assert!(root.inline_mods["tests"].test_only);
        assert!(root.macro_items);
        assert_eq!(fp.macro_spans.len(), 1);
    }
}
