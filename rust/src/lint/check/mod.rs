//! `pallas-check`: tier-2 crate-wide symbol-resolution and
//! API-consistency analysis. Where tier-1 `pallas-lint` is per-file
//! and syntactic, this pass builds a whole-crate symbol table
//! (phase 1: [`parse`] + [`resolve`]) and then resolves every
//! checkable reference against it (phase 2: [`walk`] + [`rules`] +
//! [`crate_rules`]) — catching the cross-module drift rustc only
//! reports at compile time and this repo's toolchain-less CI
//! otherwise never sees: renamed fns still named in other modules,
//! call-arity drift, struct-literal fields that no longer exist,
//! enum variants missing from hand-maintained dispatch tables.
//!
//! ## Rules
//!
//! | rule | invariant |
//! |------|-----------|
//! | `check-path-resolution` | every `a::b` path, `use` decl and `mod` decl resolves |
//! | `check-call-arity` | calls match some signature's arity (cfg twins allowed) |
//! | `check-struct-fields` | struct literals/patterns name real fields |
//! | `check-enum-variants` | variant uses match payload shape; `Event` anchors in sync |
//! | `check-trait-impls` | impl blocks match the trait's declared surface |
//! | `check-duplicate-def` | no name defined twice in one namespace/module |
//! | `check-dead-pub` | plain-`pub` items are referenced outside their file |
//!
//! ## Resolution discipline
//!
//! Three-valued: external (std/vendored/prelude), unknown
//! (macro-tainted scope, type alias, open type, possible local
//! variable), or resolved/missing. Only *missing* and concrete
//! contradictions are reported, keeping the pass zero-false-positive
//! on code rustc accepts. The deliberate false-negative surface is
//! documented per rule in `rust/LINTS.md`.
//!
//! Suppression mirrors tier 1: `// lint: allow(check-<rule>): <reason>`
//! trailing or standalone. Test regions are *not* exempt (test code
//! must resolve too) except for `check-dead-pub`, where `#[cfg(test)]`
//! items are skipped. Validated against the seeded-defect corpus in
//! `rust/tests/fixtures/check/`.

pub(crate) mod crate_rules;
pub(crate) mod parse;
pub(crate) mod resolve;
pub(crate) mod rules;
pub(crate) mod walk;

use std::collections::BTreeMap;
use std::path::Path;

use super::{
    suppression_cover, test_lines, walk_rs_files, Diagnostic, LintReport, RuleCount,
    UnusedSuppression,
};

/// The closed set of tier-2 rule names.
pub const RULES: [&str; 7] = [
    "check-path-resolution",
    "check-call-arity",
    "check-struct-fields",
    "check-enum-variants",
    "check-trait-impls",
    "check-duplicate-def",
    "check-dead-pub",
];

pub(crate) const R_PATHS: &str = "check-path-resolution";
pub(crate) const R_ARITY: &str = "check-call-arity";
pub(crate) const R_FIELDS: &str = "check-struct-fields";
pub(crate) const R_VARIANTS: &str = "check-enum-variants";
pub(crate) const R_TRAITS: &str = "check-trait-impls";
pub(crate) const R_DUP: &str = "check-duplicate-def";
pub(crate) const R_DEAD: &str = "check-dead-pub";

/// Pre-suppression findings accumulated by the rule passes.
#[derive(Debug, Default)]
pub(crate) struct Report {
    /// (file, line, rule, message).
    pub diags: Vec<(String, u32, &'static str, String)>,
    pub notes: Vec<String>,
    pub files_scanned: usize,
}

impl Report {
    pub fn diag(&mut self, file: &str, line: u32, rule: &'static str, message: String) {
        self.diags.push((file.to_string(), line, rule, message));
    }
}

/// Parse result for one comment against the *tier-2* marker grammar.
#[derive(Debug, PartialEq, Eq)]
enum CheckMarker {
    Allow { rule: String },
    /// A lint marker, but not tier-2 business (tier-1 rule, hot-path).
    Other,
    Bad(String),
}

/// Tier-2 view of a `// lint: …` comment. Tier-1 rules and `hot-path`
/// markers are `Other` (not ours, not an error); a `check-*` marker
/// with an unknown rule or a missing/empty reason is `Bad`.
fn parse_check_marker(text: &str) -> Option<CheckMarker> {
    let t = text.trim();
    let rest = t.strip_prefix("lint:")?.trim();
    if rest == "hot-path" {
        return Some(CheckMarker::Other);
    }
    if let Some(inner) = rest.strip_prefix("allow(") {
        let close = match inner.find(')') {
            Some(c) => c,
            None => return Some(CheckMarker::Bad("unterminated `allow(`".to_string())),
        };
        let rule = inner[..close].trim().to_string();
        if !rule.starts_with("check-") {
            return Some(CheckMarker::Other); // tier-1 suppression: not ours
        }
        if !RULES.contains(&rule.as_str()) {
            return Some(CheckMarker::Bad(format!("unknown rule `{rule}` in allow marker")));
        }
        let after = inner[close + 1..].trim_start();
        let reason = match after.strip_prefix(':') {
            Some(r) => r.trim(),
            None => {
                return Some(CheckMarker::Bad(format!("allow({rule}) is missing `: <reason>`")))
            }
        };
        if reason.is_empty() {
            return Some(CheckMarker::Bad(format!("allow({rule}) has an empty reason")));
        }
        return Some(CheckMarker::Allow { rule });
    }
    Some(CheckMarker::Other)
}

struct Suppression {
    rule: String,
    line: u32,
    covers: (u32, u32),
    used: bool,
}

/// Run the full tier-2 pass over the crate rooted at `src_root` (the
/// crate's `src/` directory). Errors only on unreadable directories;
/// a missing `lib.rs`/`main.rs` yields an empty crate whose on-disk
/// files all become orphan notes.
pub fn run(src_root: &Path) -> Result<LintReport, String> {
    let krate = resolve::build_crate(src_root);
    let rz = resolve::Resolver::new(&krate);
    let mut rep = Report::default();
    for (file, line, rule, message) in &krate.diags {
        rep.diag(file, *line, rule, message.clone());
    }

    // Modules grouped by defining file.
    let mut mods_by_file: BTreeMap<String, Vec<usize>> = BTreeMap::new();
    for m in krate.all_modules() {
        mods_by_file.entry(krate.modules[m].file.clone()).or_default().push(m);
    }

    // Orphan files: on disk but reachable from no crate root. They are
    // not scanned (no module scope to resolve in), only reported.
    for path in walk_rs_files(src_root)? {
        let rel = path
            .strip_prefix(src_root)
            .map_err(|e| format!("strip_prefix: {e}"))?
            .to_string_lossy()
            .replace('\\', "/");
        if !krate.files.contains_key(&rel) {
            rep.notes.push(format!("{rel}: not reachable from any crate root (orphan file)"));
        }
    }
    rep.files_scanned = krate.files.len();

    let mut test_marks: BTreeMap<String, Vec<bool>> = BTreeMap::new();
    for (rel, fp) in &krate.files {
        test_marks.insert(rel.clone(), test_lines(&fp.toks, fp.n_lines));
    }

    // Phase 2: walk each file, resolve every reference per module.
    for (rel, fp) in &krate.files {
        let Some(mods) = mods_by_file.get(rel) else {
            continue;
        };
        let spans: Vec<((usize, usize), usize)> =
            mods.iter().map(|&m| (krate.modules[m].items.tok_span, m)).collect();
        let mut walker = walk::Walker::new(fp, spans);
        for &m in mods {
            walker.prescan(&krate.modules[m].items);
        }
        let sinks = walker.walk();
        for (m, sink) in &sinks {
            rules::check_sink(&rz, *m, sink, rel, &mut rep);
        }
    }

    crate_rules::check_use_decls(&krate, &rz, &mut rep);
    crate_rules::check_trait_impls(&krate, &rz, &mut rep);
    crate_rules::check_duplicates(&krate, &mut rep);
    crate_rules::check_dead_pub(&krate, src_root, &test_marks, &mut rep);
    crate_rules::check_event_anchors(&krate, &mut rep);

    Ok(apply_suppressions(&krate, rep))
}

/// Match findings against `check-*` allow markers, producing the
/// final report. Unlike tier 1, markers inside test regions count:
/// the rules scan test code too.
fn apply_suppressions(krate: &resolve::Crate, rep: Report) -> LintReport {
    let mut sup_by_file: BTreeMap<&str, Vec<Suppression>> = BTreeMap::new();
    let mut notes = rep.notes;
    for (rel, fp) in &krate.files {
        let source = krate.sources.get(rel).map(String::as_str).unwrap_or("");
        let lines: Vec<&str> = source.lines().collect();
        let mut sups = Vec::new();
        for c in &fp.comments {
            match parse_check_marker(&c.text) {
                None | Some(CheckMarker::Other) => {}
                Some(CheckMarker::Bad(msg)) => notes.push(format!("{rel}:{}: {msg}", c.line)),
                Some(CheckMarker::Allow { rule }) => {
                    let covers = suppression_cover(c.standalone, c.line, &lines);
                    sups.push(Suppression { rule, line: c.line, covers, used: false });
                }
            }
        }
        sup_by_file.insert(rel.as_str(), sups);
    }

    let mut diags = rep.diags;
    diags.sort();
    let mut report = LintReport { schema: "pallas-check/1", ..LintReport::default() };
    for rule in RULES {
        report.rule_counts.insert(rule, RuleCount::default());
    }
    report.files_scanned = rep.files_scanned;
    for (file, line, rule, message) in diags {
        let hit = sup_by_file.get_mut(file.as_str()).and_then(|sups| {
            sups.iter_mut()
                .find(|s| s.rule == rule && s.covers.0 <= line && line <= s.covers.1)
        });
        match hit {
            Some(s) => {
                s.used = true;
                report.suppressed += 1;
                if let Some(c) = report.rule_counts.get_mut(rule) {
                    c.suppressed += 1;
                }
            }
            None => {
                if let Some(c) = report.rule_counts.get_mut(rule) {
                    c.violations += 1;
                }
                report.diagnostics.push(Diagnostic { file, line, rule, message });
            }
        }
    }
    for (rel, sups) in &sup_by_file {
        for s in sups {
            if !s.used {
                report.unused_suppressions.push(UnusedSuppression {
                    file: rel.to_string(),
                    line: s.line,
                    rule: s.rule.clone(),
                });
            }
        }
    }
    report.diagnostics.sort_by(|a, b| {
        (&a.file, a.line, a.rule, &a.message).cmp(&(&b.file, b.line, b.rule, &b.message))
    });
    report
        .unused_suppressions
        .sort_by(|a, b| (&a.file, a.line, &a.rule).cmp(&(&b.file, b.line, &b.rule)));
    notes.sort();
    report.notes = notes;
    report
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    fn write_tree(tag: &str, files: &[(&str, &str)]) -> PathBuf {
        let dir =
            std::env::temp_dir().join(format!("pallas-check-run-{}-{tag}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        for (rel, src) in files {
            let p = dir.join(rel);
            if let Some(parent) = p.parent() {
                std::fs::create_dir_all(parent).unwrap();
            }
            std::fs::write(p, src).unwrap();
        }
        dir
    }

    #[test]
    fn marker_grammar() {
        assert_eq!(parse_check_marker(" plain comment"), None);
        assert_eq!(parse_check_marker(" lint: hot-path"), Some(CheckMarker::Other));
        assert_eq!(
            parse_check_marker(" lint: allow(panic-surface): tier-1 business"),
            Some(CheckMarker::Other)
        );
        assert_eq!(
            parse_check_marker(" lint: allow(check-dead-pub): public API kept for PR 12"),
            Some(CheckMarker::Allow { rule: "check-dead-pub".to_string() })
        );
        assert!(matches!(
            parse_check_marker(" lint: allow(check-dead-pub)"),
            Some(CheckMarker::Bad(_))
        ));
        assert!(matches!(
            parse_check_marker(" lint: allow(check-dead-pub):"),
            Some(CheckMarker::Bad(_))
        ));
        assert!(matches!(
            parse_check_marker(" lint: allow(check-nonsense): reason"),
            Some(CheckMarker::Bad(_))
        ));
    }

    #[test]
    fn end_to_end_finds_and_suppresses() {
        let root = write_tree(
            "e2e",
            &[
                (
                    "lib.rs",
                    "pub mod util;\npub fn entry() -> u32 {\n    util::helper(1, 2)\n}\n",
                ),
                ("util.rs", "pub fn helper(x: u32) -> u32 { x }\n"),
            ],
        );
        let rep = run(&root).unwrap();
        assert_eq!(rep.schema, "pallas-check/1");
        let arity: Vec<_> =
            rep.diagnostics.iter().filter(|d| d.rule == "check-call-arity").collect();
        assert_eq!(arity.len(), 1, "{:?}", rep.diagnostics);
        assert!(arity[0].message.contains("called with 2 arg(s)"));
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn suppressed_finding_counts_and_unused_markers_surface() {
        let root = write_tree(
            "sup",
            &[(
                "lib.rs",
                "pub fn lonely() {}\n\
                 // lint: allow(check-dead-pub): staged API for the next PR\n\
                 pub fn also_lonely() {}\n",
            )],
        );
        let rep = run(&root).unwrap();
        // `lonely` is kept; `also_lonely` is suppressed (standalone
        // marker covers the next line).
        assert!(rep
            .diagnostics
            .iter()
            .any(|d| d.rule == "check-dead-pub" && d.message.contains("`lonely`")));
        assert!(!rep.diagnostics.iter().any(|d| d.message.contains("also_lonely")));
        assert_eq!(rep.suppressed, 1);
        assert!(rep.unused_suppressions.is_empty());
        assert!(!rep.is_clean());
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn json_is_byte_deterministic() {
        let root = write_tree(
            "det",
            &[("lib.rs", "pub mod a;\n"), ("a.rs", "pub fn f(x: u32) -> u32 { x }\n")],
        );
        let a = run(&root).unwrap().to_json();
        let b = run(&root).unwrap().to_json();
        assert_eq!(a, b);
        assert!(a.contains("\"schema\": \"pallas-check/1\""));
        let _ = std::fs::remove_dir_all(&root);
    }
}
