//! Minimal benchmark harness (criterion is not available in this build
//! environment, so `cargo bench` targets use `harness = false` binaries
//! built on this module).
//!
//! Provides warmup + repeated timed runs, robust summary statistics, and
//! a stable one-line output format the bench binaries share:
//!
//! ```text
//! bench <name>: median 12.34ms  mean 12.50ms ± 0.42ms  (n=10)
//! ```

use std::time::Instant;

/// Result of one benchmark.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub samples_ns: Vec<f64>,
}

impl BenchResult {
    pub fn median_ns(&self) -> f64 {
        let mut v = self.samples_ns.clone();
        v.sort_by(|a, b| a.total_cmp(b));
        v[v.len() / 2]
    }

    pub fn mean_ns(&self) -> f64 {
        crate::util::mean(&self.samples_ns)
    }

    pub fn std_ns(&self) -> f64 {
        let m = self.mean_ns();
        let var = self.samples_ns.iter().map(|x| (x - m) * (x - m)).sum::<f64>()
            / self.samples_ns.len().max(1) as f64;
        var.sqrt()
    }

    pub fn report(&self) -> String {
        format!(
            "bench {}: median {}  mean {} ± {}  (n={})",
            self.name,
            fmt_ns(self.median_ns()),
            fmt_ns(self.mean_ns()),
            fmt_ns(self.std_ns()),
            self.samples_ns.len()
        )
    }
}

/// Human-readable nanoseconds.
pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0}ns")
    } else if ns < 1e6 {
        format!("{:.2}µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2}ms", ns / 1e6)
    } else {
        format!("{:.2}s", ns / 1e9)
    }
}

/// Run `f` for `warmup` unmeasured + `iters` measured iterations.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_nanos() as f64);
    }
    let r = BenchResult { name: name.to_string(), samples_ns: samples };
    println!("{}", r.report());
    r
}

/// Black-box: defeat constant-folding of bench results.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_collects_samples() {
        let r = bench("noop", 1, 5, || {
            black_box(1 + 1);
        });
        assert_eq!(r.samples_ns.len(), 5);
        assert!(r.median_ns() >= 0.0);
        assert!(r.report().contains("bench noop"));
    }

    #[test]
    fn fmt_ns_scales() {
        assert_eq!(fmt_ns(500.0), "500ns");
        assert_eq!(fmt_ns(1500.0), "1.50µs");
        assert_eq!(fmt_ns(2.5e6), "2.50ms");
        assert_eq!(fmt_ns(3.2e9), "3.20s");
    }
}
