//! Cross-module integration tests: full simulations through the public
//! API, scheduler comparisons on crowded workloads, trace persistence
//! round-trips, config-to-report pipelines.

use cloudcoaster::cluster::QueuePolicy;
use cloudcoaster::coordinator::config::{ExperimentConfig, WorkloadSource};
use cloudcoaster::coordinator::report::{build_workload, run_experiment_on};
use cloudcoaster::coordinator::runner::{simulate, SimConfig};
use cloudcoaster::coordinator::sweep::paper_sweep;
use cloudcoaster::runtime::NativeAnalytics;
use cloudcoaster::sched::{Centralized, Hybrid, Scheduler, Sparrow};
use cloudcoaster::sim::Rng;
use cloudcoaster::trace::synth::{yahoo_like, YahooLikeParams};
use cloudcoaster::trace::{read_csv, write_csv, Job, Workload};
use cloudcoaster::transient::{Budget, ManagerConfig};
use cloudcoaster::util::JobId;

/// A small crowded workload: long jobs saturate most of the general
/// partition while shorts keep arriving.
fn crowded_workload(seed: u64, horizon: f64) -> Workload {
    let mut rng = Rng::new(seed);
    let mut jobs = Vec::new();
    let mut t = 0.0;
    while t < horizon {
        t += rng.exponential(3.0);
        let n = 1 + rng.below(6) as usize;
        let durs = (0..n).map(|_| rng.lognormal(2.8, 0.5)).collect();
        jobs.push(Job { id: JobId(0), arrival: t, task_durations: durs, is_long: false });
    }
    // Continuous heavy long load.
    let mut t = 0.0;
    while t < horizon {
        t += rng.exponential(40.0);
        let n = 20 + rng.below(30) as usize;
        let durs = (0..n).map(|_| rng.lognormal(6.8, 0.5)).collect();
        jobs.push(Job { id: JobId(0), arrival: t, task_durations: durs, is_long: true });
    }
    Workload::new(jobs, 90.0)
}

fn small_cfg(manager: Option<ManagerConfig>) -> SimConfig {
    SimConfig {
        n_general: 96,
        n_short_reserved: if manager.is_some() { 4 } else { 8 },
        queue_policy: QueuePolicy::Srpt { starvation_limit: 600.0 },
        manager,
        snapshot_interval: 60.0,
        steal_probes: 8,
        steal_batch: 8,
        recycle_task_slots: true,
        recycle_server_slots: true,
        exact_delay_samples: false,
        exact_snapshot_series: false,
        seed: 5,
    }
}

fn cc_manager() -> ManagerConfig {
    ManagerConfig { threshold: 0.8, ..ManagerConfig::paper(Budget::new(8, 0.5, 3.0)) }
}

#[test]
fn every_scheduler_completes_the_workload() {
    let w = crowded_workload(1, 1800.0);
    let schedulers: Vec<Box<dyn Scheduler>> = vec![
        Box::new(Centralized),
        Box::new(Sparrow::new(2.0)),
        Box::new(Hybrid::eagle(2.0)),
        Box::new(Hybrid::cloudcoaster(2.0)),
    ];
    for mut s in schedulers {
        let manager =
            (s.name() == "cloudcoaster").then(cc_manager);
        let res = simulate(&w, s.as_mut(), &small_cfg(manager));
        assert_eq!(
            res.rec.tasks_finished as usize,
            w.num_tasks(),
            "scheduler {} lost tasks",
            res.scheduler
        );
    }
}

#[test]
fn cloudcoaster_beats_eagle_on_crowded_cluster() {
    let w = crowded_workload(2, 3600.0);
    let mut eagle = Hybrid::eagle(2.0);
    let eagle_res = simulate(&w, &mut eagle, &small_cfg(None));
    let mut cc = Hybrid::cloudcoaster(2.0);
    let cc_res = simulate(&w, &mut cc, &small_cfg(Some(cc_manager())));
    let eagle_mean = eagle_res.rec.short_delays.mean();
    let cc_mean = cc_res.rec.short_delays.mean();
    assert!(
        cc_mean < eagle_mean,
        "cloudcoaster ({cc_mean:.1}s) should beat eagle ({eagle_mean:.1}s)"
    );
    // And transients were actually used, within budget at all times.
    assert!(cc_res.rec.transients_requested > 0);
    assert!(cc_res.rec.cost.max_active() <= 12.0); // K = 3 * 8 * 0.5
}

#[test]
fn long_job_performance_is_maintained() {
    // §Abstract: "while maintaining long job performance".
    let w = crowded_workload(3, 3600.0);
    let mut eagle = Hybrid::eagle(2.0);
    let eagle_res = simulate(&w, &mut eagle, &small_cfg(None));
    let mut cc = Hybrid::cloudcoaster(2.0);
    let cc_res = simulate(&w, &mut cc, &small_cfg(Some(cc_manager())));
    let eagle_long = eagle_res.rec.long_delays.mean();
    let cc_long = cc_res.rec.long_delays.mean();
    // Longs never run on transients, so their delay moves only via noise
    // (the general partition shrinks by 4 servers in the CC config).
    assert!(
        (cc_long - eagle_long).abs() / eagle_long.max(1.0) < 0.25,
        "long delay drifted: eagle {eagle_long:.0}s vs cc {cc_long:.0}s"
    );
}

#[test]
fn no_short_ever_queues_behind_a_long_under_hybrid() {
    // The hybrid invariant ("divide"): shorts avoid long-occupied servers
    // at placement time. Verify via the per-task record: every short task
    // that ran on a server marked long at its *start* must have been the
    // long-free one... simpler: spot-check queues during a paused sim is
    // impossible here, so assert the outcome instead — short p50 under
    // hybrid is far below centralized on the same crowded workload.
    let w = crowded_workload(4, 1800.0);
    let mut eagle = Hybrid::eagle(2.0);
    let eagle_res = simulate(&w, &mut eagle, &small_cfg(None));
    let mut cent = Centralized;
    let cent_res = simulate(&w, &mut cent, &small_cfg(None));
    let mut e = eagle_res.rec.short_delays.clone();
    let mut c = cent_res.rec.short_delays.clone();
    assert!(
        e.percentile(0.5) <= c.percentile(0.5),
        "eagle p50 {:.1} vs centralized p50 {:.1}",
        e.percentile(0.5),
        c.percentile(0.5)
    );
}

#[test]
fn succinct_state_is_worth_having() {
    // Eagle = Hawk + succinct state; on a long-crowded cluster the
    // long-bitmap filter must cut short-task delays (the SoCC'16 claim).
    let w = crowded_workload(7, 3600.0);
    let mut hawk = Hybrid::hawk(2.0);
    let hawk_res = simulate(&w, &mut hawk, &small_cfg(None));
    let mut eagle = Hybrid::eagle(2.0);
    let eagle_res = simulate(&w, &mut eagle, &small_cfg(None));
    let h = hawk_res.rec.short_delays.mean();
    let e = eagle_res.rec.short_delays.mean();
    assert!(e < h, "eagle ({e:.1}s) should beat hawk ({h:.1}s)");
}

#[test]
fn spot_market_bids_trade_cost_for_churn() {
    // Dynamic pricing: a tight bid must never lose tasks even when the
    // price crosses it repeatedly.
    let w = crowded_workload(8, 3600.0);
    let mut cfg = small_cfg(Some(cc_manager()));
    cfg.manager.as_mut().unwrap().market.pricing =
        Some(cloudcoaster::transient::PricingConfig { bid: 0.35, ..Default::default() });
    let mut cc = Hybrid::cloudcoaster(2.0);
    let res = simulate(&w, &mut cc, &cfg);
    assert_eq!(res.rec.tasks_finished as usize, w.num_tasks());
}

#[test]
fn trace_roundtrip_preserves_simulation_results() {
    let w = crowded_workload(6, 900.0);
    let path = std::env::temp_dir().join(format!("cc_it_{}.csv", std::process::id()));
    write_csv(&w, &path).unwrap();
    let w2 = read_csv(&path, 90.0).unwrap();
    std::fs::remove_file(&path).ok();
    let run = |w: &Workload| {
        let mut s = Hybrid::eagle(2.0);
        simulate(w, &mut s, &small_cfg(None))
    };
    let a = run(&w);
    let b = run(&w2);
    assert_eq!(a.rec.tasks_finished, b.rec.tasks_finished);
    // write_csv uses shortest-roundtrip float formatting, so the replay
    // is bit-identical (histogram state compares bit-exactly too).
    assert_eq!(a.rec.short_delays, b.rec.short_delays);
}

#[test]
fn config_pipeline_toml_to_report() {
    let cfg = ExperimentConfig::from_toml(
        r#"
        seed = 11
        [cluster]
        servers = 150
        short_partition = 10
        [transient]
        r = 3
        threshold = 0.7
        [scheduler]
        kind = "cloudcoaster"
        [workload]
        horizon = 1200
        "#,
    )
    .unwrap();
    let w = build_workload(&cfg).unwrap();
    let mut analytics = NativeAnalytics;
    let rep = run_experiment_on(&cfg, &w, &mut analytics).unwrap();
    assert!(rep.short_delay.n > 0);
    assert!(rep.cdf.values.last().copied().unwrap() > 0.999);
}

#[test]
fn paper_sweep_reproduces_figure3_ordering() {
    // Scaled-down version of the paper grid: r=3 must dominate the
    // baseline; r=1 must be in the baseline's neighbourhood.
    let mut base = ExperimentConfig::paper_defaults();
    base.cluster_size = 400;
    base.short_partition = 16;
    base.threshold = 0.8;
    let mut p = YahooLikeParams::default();
    p.horizon = 3.0 * 3600.0;
    p.short_arrivals.calm_rate /= 10.0;
    p.short_arrivals.burst_rate /= 10.0;
    p.long_arrivals.calm_rate /= 5.0;
    p.long_arrivals.burst_rate /= 5.0;
    p.long_arrivals.calm_dwell /= 6.0;
    p.long_arrivals.burst_dwell /= 6.0;
    base.workload = WorkloadSource::YahooLike(p);
    let reports = paper_sweep(&base, &[1.0, 3.0]).unwrap();
    let baseline = &reports[0];
    let r3 = &reports[2];
    assert!(baseline.short_delay.mean > 0.0);
    assert!(
        r3.short_delay.mean < baseline.short_delay.mean,
        "r=3 ({:.1}s) must beat baseline ({:.1}s)",
        r3.short_delay.mean,
        baseline.short_delay.mean
    );
}

#[test]
fn deterministic_end_to_end() {
    let mut cfg = ExperimentConfig::paper_defaults();
    cfg.cluster_size = 200;
    cfg.short_partition = 10;
    cfg.threshold = 0.8;
    if let WorkloadSource::YahooLike(p) = &mut cfg.workload {
        p.horizon = 1200.0;
        p.short_arrivals.calm_rate /= 10.0;
        p.short_arrivals.burst_rate /= 10.0;
    }
    let w = build_workload(&cfg).unwrap();
    let mut analytics = NativeAnalytics;
    let a = run_experiment_on(&cfg, &w, &mut analytics).unwrap();
    let b = run_experiment_on(&cfg, &w, &mut analytics).unwrap();
    assert_eq!(a.short_delay.n, b.short_delay.n);
    assert_eq!(a.short_delay.mean, b.short_delay.mean);
    assert_eq!(a.events, b.events);
}

#[test]
fn yahoo_like_trace_matches_published_shape() {
    // DESIGN.md §3 substitution: the synthetic trace must match the shape
    // statistics Eagle/Hawk report for the Yahoo trace.
    let w = yahoo_like(&YahooLikeParams::default(), &mut Rng::new(42));
    let stats = cloudcoaster::trace::TraceStats::of(&w);
    assert!(stats.short_job_frac > 0.9, "short fraction {}", stats.short_job_frac);
    assert!(stats.long_work_frac > 0.9, "long work {}", stats.long_work_frac);
    assert!(stats.mean_long_duration / stats.mean_short_duration > 20.0);
    assert!(stats.jobs > 15_000 && stats.jobs < 40_000, "jobs {}", stats.jobs);
}
