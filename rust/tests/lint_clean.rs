//! Static-analysis gates: the tree must be `pallas-lint`-clean (tier 1)
//! and `pallas-check`-clean (tier 2).
//!
//! Runs both passes in-process over `src/**` (same entry points the
//! binaries use) and fails with the human-readable report if any
//! unsuppressed diagnostic remains. Repeat runs pin the JSON reports
//! byte-for-byte, so CI can diff artifacts across commits without
//! timestamp or ordering noise. The tier-2 pass is additionally
//! validated against the seeded-defect corpus in
//! `tests/fixtures/check/`: every planted defect must be caught under
//! its expected rule, and every clean twin must pass strictly.

use std::path::Path;

use cloudcoaster::lint;

fn src_root() -> std::path::PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("src")
}

/// The whole crate carries zero unsuppressed diagnostics. Every known
/// exception is a `// lint: allow(<rule>): <reason>` at the site, so a
/// failure here means new code broke an invariant (or an allow lost its
/// anchor line in a refactor) — the printed report says which and where.
#[test]
fn tree_is_lint_clean() {
    let report = lint::run(&src_root()).expect("lint walk over src/ failed");
    assert!(
        report.files_scanned > 0,
        "lint walk found no .rs files under {}",
        src_root().display()
    );
    assert!(
        report.is_clean(),
        "pallas-lint found unsuppressed diagnostics:\n\n{}",
        report.render_human()
    );
}

/// Two independent runs over the same tree serialize to byte-identical JSON:
/// no timestamps, no absolute paths, no hash-order leakage.
#[test]
fn json_report_is_byte_deterministic() {
    let a = lint::run(&src_root()).expect("first lint run failed").to_json();
    let b = lint::run(&src_root()).expect("second lint run failed").to_json();
    assert_eq!(a, b, "pallas-lint JSON output is not run-to-run deterministic");
    assert!(
        !a.contains(&src_root().display().to_string()),
        "JSON report leaks the absolute source root"
    );
}

/// Tier-2 gate: crate-wide symbol resolution and API consistency. The
/// strict form — unused `check-*` suppressions fail too, so stale
/// markers can't accumulate. Also pins JSON byte-determinism and the
/// schema tag for the CI artifact diff.
#[test]
fn pallas_check_clean() {
    let report = lint::check::run(&src_root()).expect("check walk over src/ failed");
    assert!(report.files_scanned > 0, "pallas-check scanned no files");
    assert_eq!(report.schema, "pallas-check/1");
    assert!(
        report.is_clean_strict(),
        "pallas-check found unsuppressed diagnostics or unused suppressions:\n\n{}",
        report.render_human()
    );
    let again = lint::check::run(&src_root()).expect("second check run failed");
    assert_eq!(
        report.to_json(),
        again.to_json(),
        "pallas-check JSON output is not run-to-run deterministic"
    );
    assert!(
        !report.to_json().contains(&src_root().display().to_string()),
        "JSON report leaks the absolute source root"
    );
}

/// Recall over the seeded-defect corpus: every `defect/` tree fires at
/// least one finding under the rule named in its `EXPECT` file (and no
/// finding under any other rule — fixtures are single-defect), and
/// every `clean/` twin passes the strict gate. A regression in any
/// resolver or rule shows up here as a named fixture, not a vague diff.
#[test]
fn check_corpus_recall() {
    let corpus = Path::new(env!("CARGO_MANIFEST_DIR")).join("tests/fixtures/check");
    let mut cases: Vec<std::path::PathBuf> = std::fs::read_dir(&corpus)
        .expect("fixture corpus missing")
        .filter_map(|e| e.ok().map(|e| e.path()))
        .filter(|p| p.is_dir())
        .collect();
    cases.sort();
    assert!(cases.len() >= 25, "corpus shrank: only {} cases", cases.len());

    for case in &cases {
        let name = case.file_name().unwrap().to_string_lossy().to_string();
        let expect = std::fs::read_to_string(case.join("EXPECT"))
            .unwrap_or_else(|e| panic!("{name}: EXPECT unreadable: {e}"));
        let expect = expect.trim();
        assert!(
            lint::check::RULES.contains(&expect),
            "{name}: EXPECT names unknown rule `{expect}`"
        );

        let defect = lint::check::run(&case.join("defect"))
            .unwrap_or_else(|e| panic!("{name}: defect run failed: {e}"));
        assert!(
            defect.diagnostics.iter().any(|d| d.rule == expect),
            "{name}: planted `{expect}` defect NOT caught; report:\n{}",
            defect.render_human()
        );
        let off_rule: Vec<_> =
            defect.diagnostics.iter().filter(|d| d.rule != expect).collect();
        assert!(
            off_rule.is_empty(),
            "{name}: off-rule findings in single-defect fixture: {off_rule:?}"
        );

        let clean = lint::check::run(&case.join("clean"))
            .unwrap_or_else(|e| panic!("{name}: clean run failed: {e}"));
        assert!(
            clean.is_clean_strict(),
            "{name}: clean twin is not clean:\n{}",
            clean.render_human()
        );
    }
}
