//! Tier-1 gate: the tree must be `pallas-lint`-clean.
//!
//! Runs the full lint pass in-process over `src/**` (same entry point
//! the `pallas-lint` binary uses) and fails with the human-readable
//! report if any unsuppressed diagnostic remains. A second run pins the
//! JSON report byte-for-byte, so CI can diff artifacts across commits
//! without timestamp or ordering noise.

use std::path::Path;

use cloudcoaster::lint;

fn src_root() -> std::path::PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("src")
}

/// The whole crate carries zero unsuppressed diagnostics. Every known
/// exception is a `// lint: allow(<rule>): <reason>` at the site, so a
/// failure here means new code broke an invariant (or an allow lost its
/// anchor line in a refactor) — the printed report says which and where.
#[test]
fn tree_is_lint_clean() {
    let report = lint::run(&src_root()).expect("lint walk over src/ failed");
    assert!(
        report.files_scanned > 0,
        "lint walk found no .rs files under {}",
        src_root().display()
    );
    assert!(
        report.is_clean(),
        "pallas-lint found unsuppressed diagnostics:\n\n{}",
        report.render_human()
    );
}

/// Two independent runs over the same tree serialize to byte-identical JSON:
/// no timestamps, no absolute paths, no hash-order leakage.
#[test]
fn json_report_is_byte_deterministic() {
    let a = lint::run(&src_root()).expect("first lint run failed").to_json();
    let b = lint::run(&src_root()).expect("second lint run failed").to_json();
    assert_eq!(a, b, "pallas-lint JSON output is not run-to-run deterministic");
    assert!(
        !a.contains(&src_root().display().to_string()),
        "JSON report leaks the absolute source root"
    );
}
