//! Order-equivalence properties for the calendar-queue event engine.
//!
//! The calendar queue must pop in *exactly* the `(time, seq)` order of
//! the `BinaryHeap` it replaced — that total order is what every
//! determinism golden rests on. These tests drive randomized and
//! adversarial schedule/pop interleavings (equal-time tie storms,
//! far-future overflow events, rollover boundaries, skip-ahead reopen
//! paths) against the reference heap engine kept in-tree
//! ([`Engine::reference`]), and pin the panic contract for NaN /
//! infinite / past times.

use cloudcoaster::sim::{Engine, Event, Rng};
use cloudcoaster::testkit::{property, uniform, usize_in};
use cloudcoaster::util::JobId;

/// Distinct payloads so an order mismatch is visible even among
/// equal-time events (seq-order check).
fn ev(i: u32) -> Event {
    Event::JobArrival(JobId(i))
}

/// A randomized schedule/pop script replayed identically onto several
/// engines. Times are engine-clock-relative offsets, so the script is
/// valid (never past-scheduling) regardless of representation.
enum Op {
    /// Schedule at `now + offset` (offset >= 0).
    Push(f64),
    /// Re-schedule at exactly the last pushed absolute time, if still
    /// >= now (tie storms across interleaved pops).
    PushTie,
    Pop,
    PopBatch,
}

/// Generate a script mixing dense MMPP-ish churn, exact-tie storms,
/// far-future overflow pushes (revocation-horizon shape) and drain
/// phases that force rollovers and the skip-ahead reopen path.
fn random_script(rng: &mut Rng, len: usize) -> Vec<Op> {
    let mut ops = Vec::with_capacity(len);
    for _ in 0..len {
        match rng.below(10) {
            0..=3 => {
                // Near-term churn at two very different scales so the
                // self-tuned width is wrong for part of the stream.
                let mean = if rng.below(2) == 0 { 0.3 } else { 300.0 };
                ops.push(Op::Push(rng.exponential(mean)));
            }
            4 => ops.push(Op::Push(0.0)), // at the current clock
            5 => ops.push(Op::PushTie),
            6 => {
                // Far future: lands in the overflow rung, popped only
                // after a window rollover.
                ops.push(Op::Push(1e6 + uniform(rng, 0.0, 1e9)));
            }
            _ => {
                if rng.below(4) == 0 {
                    ops.push(Op::PopBatch);
                } else {
                    ops.push(Op::Pop);
                }
            }
        }
    }
    ops
}

/// Replay `script` on `engine`, recording every popped `(time-bits,
/// event)` and checking `peek_time` coherence throughout, then drain to
/// quiescence. `PopBatch` flattens into the same per-event stream.
fn replay(mut engine: Engine, script: &[Op]) -> Vec<(u64, Event)> {
    let mut popped = Vec::new();
    let mut batch = Vec::new();
    let mut last_abs: Option<f64> = None;
    for op in script {
        match op {
            Op::Push(offset) => {
                let at = engine.now() + offset;
                engine.schedule(at, ev(popped.len() as u32 + engine.pending() as u32));
                last_abs = Some(at);
            }
            Op::PushTie => {
                if let Some(at) = last_abs {
                    if at >= engine.now() {
                        engine.schedule(at, ev(popped.len() as u32 + engine.pending() as u32));
                    }
                }
            }
            Op::Pop => {
                let peeked = engine.peek_time();
                if let Some((t, e)) = engine.pop() {
                    assert_eq!(peeked, Some(t), "peek_time disagreed with pop");
                    popped.push((t.to_bits(), e));
                }
            }
            Op::PopBatch => {
                let peeked = engine.peek_time();
                if let Some(t) = engine.pop_batch(&mut batch) {
                    assert_eq!(peeked, Some(t), "peek_time disagreed with pop_batch");
                    assert!(!batch.is_empty(), "nonempty batch for a popped timestamp");
                    for &e in &batch {
                        popped.push((t.to_bits(), e));
                    }
                }
            }
        }
    }
    while let Some((t, e)) = engine.pop() {
        popped.push((t.to_bits(), e));
    }
    assert_eq!(engine.pending(), 0);
    assert_eq!(engine.processed(), popped.len() as u64);
    popped
}

/// The payload-id scheme in `replay` depends only on (pops so far,
/// pending count), both of which are representation-independent — so
/// two engines replaying the same script assign identical payloads and
/// their pop streams are comparable element-for-element.
#[test]
fn randomized_interleavings_match_heap_oracle() {
    property("engine/calendar_matches_heap_oracle", 60, |rng| {
        let len = usize_in(rng, 50, 1200);
        let script = random_script(rng, len);
        let oracle = replay(Engine::reference(), &script);
        // Several calendar pre-sizes: a degenerate hint forces early
        // grows; a huge one forces shrink passes on drain.
        for hint in [1usize, 64, 1 << 14] {
            let got = replay(Engine::with_capacity(hint), &script);
            assert_eq!(got, oracle, "calendar(hint={hint}) diverged from heap oracle");
        }
    });
}

#[test]
fn tie_storms_preserve_insertion_order() {
    property("engine/tie_storm_seq_order", 30, |rng| {
        let mut cal = Engine::with_capacity(usize_in(rng, 1, 512));
        let mut heap = Engine::reference();
        let storms = usize_in(rng, 1, 8);
        let mut id = 0u32;
        for s in 0..storms {
            let t = (s * 7) as f64 + uniform(rng, 0.0, 3.0);
            let width = usize_in(rng, 1, 400);
            for _ in 0..width {
                for e in [&mut cal, &mut heap] {
                    e.schedule(t, ev(id));
                }
                id += 1;
            }
            // Interleave pops mid-storm so the open bucket is partially
            // consumed when the next burst lands.
            if rng.below(2) == 0 {
                assert_eq!(cal.pop(), heap.pop());
            }
        }
        let mut last: Option<(u64, u32)> = None;
        loop {
            let (c, h) = (cal.pop(), heap.pop());
            assert_eq!(c, h);
            let Some((t, e)) = c else { break };
            let Event::JobArrival(j) = e else { unreachable!() };
            if let Some((lt, lj)) = last {
                assert!(
                    t.to_bits() > lt || j.0 > lj,
                    "equal-time events out of insertion order"
                );
            }
            last = Some((t.to_bits(), j.0));
        }
    });
}

#[test]
fn rollover_and_reopen_boundaries_match_oracle() {
    property("engine/rollover_reopen", 30, |rng| {
        // Sparse far-apart events force repeated rollovers; after each
        // pop, a near-term event exercises the reopen path (scheduling
        // behind a skipped-ahead open bucket).
        let mut script = Vec::new();
        let clusters = usize_in(rng, 2, 12);
        for _ in 0..clusters {
            script.push(Op::Push(uniform(rng, 1e4, 1e8)));
        }
        for _ in 0..clusters {
            script.push(Op::Pop);
            script.push(Op::Push(uniform(rng, 0.0, 2.0)));
            script.push(Op::PushTie);
        }
        let oracle = replay(Engine::reference(), &script);
        let got = replay(Engine::with_capacity(usize_in(rng, 1, 64)), &script);
        assert_eq!(got, oracle);
    });
}

#[test]
fn pop_batch_is_pop_loop_on_both_engines() {
    property("engine/pop_batch_equivalence", 30, |rng| {
        let len = usize_in(rng, 50, 600);
        let script: Vec<Op> = random_script(rng, len)
            .into_iter()
            .map(|op| if matches!(op, Op::PopBatch) { Op::Pop } else { op })
            .collect();
        let batched: Vec<Op> = script
            .iter()
            .map(|op| match op {
                Op::Pop => Op::PopBatch,
                Op::Push(x) => Op::Push(*x),
                Op::PushTie => Op::PushTie,
                Op::PopBatch => unreachable!(),
            })
            .collect();
        // pop_batch drains whole timestamp runs, so the batched replay
        // pops *at least* as much per op — but the drain phase at the
        // end of `replay` equalizes total coverage, and the per-event
        // stream must be identical on both representations.
        let per_pop_cal = replay(Engine::new(), &script);
        let per_pop_heap = replay(Engine::reference(), &script);
        assert_eq!(per_pop_cal, per_pop_heap);
        let batch_cal = replay(Engine::new(), &batched);
        let batch_heap = replay(Engine::reference(), &batched);
        assert_eq!(batch_cal, batch_heap);
    });
}

#[test]
fn drain_only_batches_have_strictly_increasing_times() {
    property("engine/batch_maximality", 20, |rng| {
        let mut e = Engine::with_capacity(usize_in(rng, 1, 128));
        let n = usize_in(rng, 10, 300);
        for i in 0..n {
            // Coarse-quantized times generate plenty of exact ties.
            let t = (usize_in(rng, 0, 40) as f64) * 2.5;
            e.schedule(t, ev(i as u32));
        }
        let mut batch = Vec::new();
        let mut last = f64::NEG_INFINITY;
        let mut total = 0;
        while let Some(t) = e.pop_batch(&mut batch) {
            assert!(
                t > last,
                "maximal same-timestamp runs imply strictly increasing batch times"
            );
            total += batch.len();
            last = t;
        }
        assert_eq!(total, n);
    });
}

#[test]
#[should_panic(expected = "scheduling into the past")]
fn calendar_rejects_past_times() {
    let mut e = Engine::new();
    e.schedule(10.0, Event::Snapshot);
    e.pop();
    e.schedule(9.0, Event::Snapshot);
}

#[test]
#[should_panic(expected = "scheduling into the past")]
fn reference_rejects_past_times() {
    let mut e = Engine::reference();
    e.schedule(10.0, Event::Snapshot);
    e.pop();
    e.schedule(9.0, Event::Snapshot);
}

#[test]
#[should_panic(expected = "NaN event time")]
fn calendar_rejects_nan_times() {
    Engine::new().schedule(f64::NAN, Event::Snapshot);
}

#[test]
#[should_panic(expected = "NaN event time")]
fn reference_rejects_nan_times() {
    Engine::reference().schedule(f64::NAN, Event::Snapshot);
}

#[test]
#[should_panic(expected = "non-finite event time")]
fn calendar_rejects_infinite_times() {
    Engine::new().schedule(f64::INFINITY, Event::Snapshot);
}

/// End-to-end pin: a full simulation on the reference engine is
/// bit-identical to the calendar engine on every distilled field (the
/// CI smoke diffs the same thing through the CLI).
#[test]
fn reference_engine_run_is_bit_identical() {
    use cloudcoaster::coordinator::runner::{simulate, SimConfig};
    use cloudcoaster::sched::Hybrid;
    use cloudcoaster::trace::synth::{yahoo_like, YahooLikeParams};
    use cloudcoaster::transient::{Budget, ManagerConfig};

    let mut p = YahooLikeParams::default();
    p.horizon = 3000.0;
    let w = yahoo_like(&p, &mut Rng::new(11));
    let run = |reference: bool| {
        let mut cfg = SimConfig {
            n_general: 120,
            n_short_reserved: 4,
            reference_engine: reference,
            ..Default::default()
        };
        cfg.manager = Some(ManagerConfig {
            threshold: 0.6,
            ..ManagerConfig::paper(Budget::new(8, 0.5, 3.0))
        });
        let mut sched = Hybrid::cloudcoaster(2.0);
        simulate(&w, &mut sched, &cfg)
    };
    let cal = run(false);
    let heap = run(true);
    assert_eq!(cal.events, heap.events);
    assert_eq!(cal.end_time.to_bits(), heap.end_time.to_bits());
    assert_eq!(cal.rec.tasks_finished, heap.rec.tasks_finished);
    assert_eq!(cal.rec.short_delays, heap.rec.short_delays);
    assert_eq!(cal.rec.long_delays, heap.rec.long_delays);
    assert_eq!(cal.rec.transients_requested, heap.rec.transients_requested);
    assert_eq!(cal.manager_stats, heap.manager_stats);
    assert_eq!(cal.peak_resident_tasks, heap.peak_resident_tasks);
    assert_eq!(cal.peak_resident_servers, heap.peak_resident_servers);
}
