//! Paper-fidelity tests: every closed-form statement in §3 and every
//! configuration constant in §4 is checked against the implementation.

use cloudcoaster::cluster::{Cluster, QueuePolicy};
use cloudcoaster::coordinator::config::ExperimentConfig;
use cloudcoaster::metrics::Recorder;
use cloudcoaster::sim::{Engine, Rng};
use cloudcoaster::transient::{Budget, ManagerConfig, MarketConfig, TransientManager};
use cloudcoaster::util::JobId;

// ----------------------------------------------------------------- §3.1

#[test]
fn sec31_cost_ratio_formula_t() {
    // T = N((r-1)p + 1); the §3.1 worked example: N=80? the paper uses
    // T = 2N for r=3, p=0.5.
    for n in [40usize, 80, 160] {
        let b = Budget::new(n, 0.5, 3.0);
        assert_eq!(b.max_partition(), 2 * n);
    }
}

#[test]
fn sec31_k_equals_rnp() {
    for (r, p, n, k) in [(3.0, 0.5, 80, 120), (2.0, 0.5, 80, 80), (1.0, 0.5, 80, 40)] {
        assert_eq!(Budget::new(n, p, r).max_transients(), k);
    }
}

// ----------------------------------------------------------------- §3.2

#[test]
fn sec32_lr_definition() {
    // l_r = N_long / N_total where N_long counts servers *with* long
    // tasks (not long tasks themselves).
    let mut cluster = Cluster::new(10, 0, QueuePolicy::Fifo);
    let mut engine = Engine::new();
    let mut rec = Recorder::new(1.0);
    // Two long tasks on the same server count once.
    for _ in 0..2 {
        let t = cluster.add_task(JobId(0), 100.0, true, 0.0);
        cluster.enqueue(t, cloudcoaster::util::ServerRef::initial(0), &mut engine, &mut rec);
    }
    assert_eq!(cluster.n_long_servers(), 1);
    assert!((cluster.long_load_ratio() - 0.1).abs() < 1e-12);
}

#[test]
fn sec32_lr_initialised_to_zero() {
    let cluster = Cluster::new(100, 10, QueuePolicy::Fifo);
    assert_eq!(cluster.long_load_ratio(), 0.0);
}

#[test]
fn sec32_add_above_remove_below_threshold() {
    let mut cluster = Cluster::new(10, 2, QueuePolicy::Fifo);
    let mut engine = Engine::new();
    let mut rec = Recorder::new(3.0);
    let cfg = ManagerConfig {
        threshold: 0.5,
        drain_cooldown: 0.0,
        ..ManagerConfig::paper(Budget::new(8, 0.5, 3.0))
    };
    let mut mgr = TransientManager::new(cfg, Rng::new(1));
    // Below threshold with no transients: no-op.
    mgr.maybe_resize(&mut cluster, &mut engine, &mut rec);
    assert_eq!(mgr.pending(), 0);
    // Push l_r to 0.7 (> 0.5): manager must lease.
    for i in 0..7 {
        let t = cluster.add_task(JobId(0), 1e4, true, 0.0);
        cluster.enqueue(t, cloudcoaster::util::ServerRef::initial(i), &mut engine, &mut rec);
    }
    mgr.maybe_resize(&mut cluster, &mut engine, &mut rec);
    assert!(mgr.pending() > 0, "no lease despite l_r > L_r^T");
}

#[test]
fn sec32_graceful_release_completes_queue() {
    // "CloudCoaster instructs the server to complete all of its currently
    // enqueued tasks before shutting down."
    let mut cluster = Cluster::new(4, 0, QueuePolicy::Fifo);
    let mut engine = Engine::new();
    let mut rec = Recorder::new(3.0);
    let sid = cluster.request_transient(0.0);
    cluster.transient_ready(sid, 0.0, &mut rec);
    for _ in 0..3 {
        let t = cluster.add_task(JobId(0), 10.0, false, 0.0);
        cluster.enqueue(t, sid, &mut engine, &mut rec);
    }
    assert!(!cluster.begin_drain(sid)); // busy -> drains later
    let mut finished = 0;
    while let Some((_, ev)) = engine.pop() {
        if let cloudcoaster::sim::Event::TaskFinish { server, task } = ev {
            match cluster.on_task_finish(server, task, &mut engine, &mut rec) {
                cloudcoaster::cluster::FinishOutcome::Finished { drained, .. } => {
                    finished += 1;
                    if drained {
                        cluster.retire(server, engine.now(), &mut rec);
                    }
                }
                cloudcoaster::cluster::FinishOutcome::Stale => {}
            }
        }
    }
    assert_eq!(finished, 3); // every enqueued task completed
    assert_eq!(rec.cost.lifetimes.len(), 1); // then it shut down
}

// ----------------------------------------------------------------- §3.3

#[test]
fn sec33_at_least_one_ondemand_copy_survives_revocation() {
    // A short task enqueued on a transient with an on-demand copy must
    // survive revocation without rescheduling.
    let mut cluster = Cluster::new(4, 2, QueuePolicy::Fifo);
    let mut engine = Engine::new();
    let mut rec = Recorder::new(3.0);
    let sid = cluster.request_transient(0.0);
    cluster.transient_ready(sid, 0.0, &mut rec);
    let od = cluster.short_reserved[0];
    // Busy both so the copies queue.
    for target in [sid, od] {
        let b = cluster.add_task(JobId(0), 100.0, false, 0.0);
        cluster.enqueue(b, target, &mut engine, &mut rec);
    }
    let t = cluster.add_task(JobId(1), 10.0, false, 0.0);
    cluster.enqueue(t, sid, &mut engine, &mut rec);
    cluster.enqueue(t, od, &mut engine, &mut rec);
    let orphans = cluster.revoke(sid, 1.0, &mut rec);
    assert!(!orphans.contains(&t), "duplicated task must not orphan");
    // World completes; the task runs exactly once (on the od copy). The
    // arena filters the revoked execution's stale finish itself.
    let mut t_finishes = 0;
    while let Some((_, ev)) = engine.pop() {
        if let cloudcoaster::sim::Event::TaskFinish { server, task } = ev {
            if let cloudcoaster::cluster::FinishOutcome::Finished { job, .. } =
                cluster.on_task_finish(server, task, &mut engine, &mut rec)
            {
                if task == t {
                    assert_eq!(job, JobId(1));
                    t_finishes += 1;
                }
            }
        }
    }
    assert_eq!(t_finishes, 1, "duplicated task must run exactly once");
    // All liveness refs settled: the slot has been recycled, which is
    // the arena's way of saying "finished and fully settled".
    assert!(cluster.get_task(t).is_none());
    assert_eq!(rec.tasks_rescheduled, 0);
}

#[test]
fn sec33_revocation_warning_is_30s_by_default() {
    assert_eq!(MarketConfig::default().revocation_warning, 30.0);
}

// ------------------------------------------------------------------- §4

#[test]
fn sec4_paper_configuration_constants() {
    let cfg = ExperimentConfig::paper_defaults();
    assert_eq!(cfg.cluster_size, 4000, "4000 on-demand servers");
    assert_eq!(cfg.short_partition, 80, "80 used for short jobs");
    assert_eq!(cfg.p, 0.5, "p = 0.5");
    assert_eq!(cfg.threshold, 0.95, "L_r^T = 0.95");
    assert_eq!(cfg.provisioning_delay, 120.0, "120 s provisioning delay");
    assert_eq!(cfg.mttf, None, "paper regime: no revocations observed");
}

#[test]
fn sec4_transient_caps_by_ratio() {
    // "CloudCoaster can use up to 40, 80 and 120 transient servers."
    for (r, cap) in [(1.0, 40), (2.0, 80), (3.0, 120)] {
        let mut cfg = ExperimentConfig::paper_defaults();
        cfg.r = r;
        let sim = cfg.to_sim_config();
        assert_eq!(sim.manager.unwrap().budget.max_transients(), cap);
        assert_eq!(sim.n_short_reserved, 40); // (1-p) * 80 buffer servers
    }
}

#[test]
fn sec42_r_normalised_accounting() {
    // Table 1's metric: avg transients / r, compared to 40 on-demand.
    let mut ledger = cloudcoaster::metrics::CostLedger::new(3.0);
    for _ in 0..90 {
        ledger.transient_up(0.0);
    }
    for _ in 0..90 {
        ledger.transient_down(3600.0, 3600.0);
    }
    // 90 transients for 1h of a 1h sim -> avg 90, r-norm 30, saving 25%.
    assert!((ledger.avg_active(3600.0) - 90.0).abs() < 1e-9);
    assert!((ledger.r_normalized_avg(3600.0) - 30.0).abs() < 1e-9);
    assert!((ledger.saving_vs_static(40.0, 3600.0) - 0.25).abs() < 1e-9);
}
