//! Property-based tests (via `testkit::property` — seeded randomized
//! invariant checks, our stand-in for proptest in this offline build):
//! conservation laws, budget bounds, l_r bounds and full cluster
//! invariants across randomized scenarios.

use cloudcoaster::cluster::{Cluster, QueuePolicy, ServerState, TaskState};
use cloudcoaster::coordinator::runner::{simulate, SimConfig};
use cloudcoaster::metrics::Recorder;
use cloudcoaster::sched::Hybrid;
use cloudcoaster::sim::{Engine, Event, Rng};
use cloudcoaster::testkit::{property, usize_in};
use cloudcoaster::trace::{Job, Workload};
use cloudcoaster::transient::{Budget, ManagerConfig};
use cloudcoaster::util::JobId;

fn random_workload(rng: &mut Rng, horizon: f64) -> Workload {
    let mut jobs = Vec::new();
    let mut t = 0.0;
    while t < horizon {
        t += rng.exponential(5.0);
        let is_long = rng.f64() < 0.1;
        let n = 1 + rng.below(if is_long { 24 } else { 8 }) as usize;
        let (mu, sigma) = if is_long { (6.5, 0.6) } else { (2.8, 0.6) };
        let durs = (0..n).map(|_| rng.lognormal(mu, sigma)).collect();
        jobs.push(Job { id: JobId(0), arrival: t, task_durations: durs, is_long });
    }
    Workload::new(jobs, 90.0)
}

fn random_cfg(rng: &mut Rng, with_manager: bool) -> SimConfig {
    let n_general = usize_in(rng, 24, 128);
    let n_short = usize_in(rng, 2, 12);
    let manager = with_manager.then(|| ManagerConfig {
        threshold: 0.3 + 0.65 * rng.f64(),
        drain_cooldown: if rng.f64() < 0.5 { 0.0 } else { 120.0 },
        max_removals_per_recalc: usize_in(rng, 1, 3),
        ..ManagerConfig::paper(Budget::new(
            n_short.max(2),
            0.25 + 0.5 * rng.f64(),
            1.0 + 3.0 * rng.f64(),
        ))
    });
    SimConfig {
        n_general,
        n_short_reserved: n_short,
        queue_policy: if rng.f64() < 0.3 {
            QueuePolicy::Fifo
        } else {
            QueuePolicy::Srpt { starvation_limit: 100.0 + 900.0 * rng.f64() }
        },
        manager,
        snapshot_interval: 60.0,
        steal_probes: usize_in(rng, 0, 8),
        steal_batch: usize_in(rng, 1, 16),
        // Exercise all arena/backend modes: recycling (default) and the
        // append-only / exact-sample reference modes. Every property
        // must hold in every combination.
        recycle_task_slots: rng.f64() < 0.8,
        recycle_server_slots: rng.f64() < 0.8,
        exact_delay_samples: rng.f64() < 0.25,
        exact_snapshot_series: rng.f64() < 0.25,
        seed: rng.next_u64(),
    }
}

#[test]
fn prop_every_task_finishes_exactly_once() {
    property("conservation of tasks", 25, |rng| {
        let horizon = 400.0 + 800.0 * rng.f64();
        let w = random_workload(rng, horizon);
        let with_manager = rng.f64() < 0.7;
        let cfg = random_cfg(rng, with_manager);
        let mut sched = if rng.f64() < 0.5 {
            Hybrid::eagle(2.0)
        } else {
            Hybrid::cloudcoaster(2.0)
        };
        let res = simulate(&w, &mut sched, &cfg);
        assert_eq!(res.rec.tasks_finished as usize, w.num_tasks());
        assert_eq!(
            res.rec.short_delays.len() + res.rec.long_delays.len() as usize,
            w.num_tasks(),
            "delay samples != tasks"
        );
    });
}

#[test]
fn prop_budget_cap_never_exceeded() {
    property("budget cap", 15, |rng| {
        let w = random_workload(rng, 800.0);
        let cfg = random_cfg(rng, true);
        let cap = cfg.manager.as_ref().unwrap().budget.max_transients() as f64;
        let mut sched = Hybrid::cloudcoaster(2.0);
        let res = simulate(&w, &mut sched, &cfg);
        assert!(
            res.rec.cost.max_active() <= cap,
            "fleet {} exceeded K={cap}",
            res.rec.cost.max_active()
        );
    });
}

#[test]
fn prop_delays_nonnegative_and_lr_bounded() {
    property("delay & l_r bounds", 15, |rng| {
        let w = random_workload(rng, 600.0);
        let cfg = random_cfg(rng, true);
        let mut sched = Hybrid::cloudcoaster(2.0);
        let res = simulate(&w, &mut sched, &cfg);
        // Nonnegativity via the exact min (bit-identical across delay
        // backends, so this holds whichever mode random_cfg picked).
        assert!(res.rec.short_delays.min() >= 0.0);
        assert!(res.rec.long_delays.min() >= 0.0);
        if let Some(samples) = res.rec.short_delays.samples() {
            assert!(samples.iter().all(|&d| d >= 0.0));
        }
        for &(_, lr) in &res.rec.lr_series.points {
            assert!((0.0..=1.0).contains(&lr), "l_r out of bounds: {lr}");
        }
    });
}

#[test]
fn prop_revocations_never_lose_tasks() {
    property("revocation safety", 15, |rng| {
        let w = random_workload(rng, 600.0);
        let mut cfg = random_cfg(rng, true);
        let mgr = cfg.manager.as_mut().unwrap();
        mgr.threshold = 0.4; // keep transients in play
        mgr.market.mttf = Some(120.0 + 1200.0 * rng.f64()); // heavy revocations
        let mut sched = Hybrid::cloudcoaster(2.0);
        let res = simulate(&w, &mut sched, &cfg);
        assert_eq!(res.rec.tasks_finished as usize, w.num_tasks());
    });
}

#[test]
fn prop_cluster_invariants_hold_under_random_ops() {
    // Drive the Cluster state machine directly with random operations and
    // check the full invariant set after every step.
    property("cluster state machine", 20, |rng| {
        let mut cluster = Cluster::new(usize_in(rng, 4, 16), usize_in(rng, 1, 4), QueuePolicy::Fifo);
        let mut engine = Engine::new();
        let mut rec = Recorder::new(2.0);
        let mut transients: Vec<cloudcoaster::util::ServerRef> = Vec::new();
        for step in 0..200 {
            match rng.below(10) {
                0..=4 => {
                    // Enqueue a task on a random accepting server.
                    let accepting: Vec<_> = cluster
                        .servers
                        .iter()
                        .filter(|s| s.accepting())
                        .map(|s| s.id)
                        .collect();
                    if let Some(&sid) =
                        accepting.get(rng.below(accepting.len().max(1) as u64) as usize)
                    {
                        let is_long = rng.f64() < 0.3;
                        let t = cluster.add_task(
                            JobId(step),
                            1.0 + rng.f64() * 50.0,
                            is_long,
                            engine.now(),
                        );
                        cluster.enqueue(t, sid, &mut engine, &mut rec);
                    }
                }
                5..=6 => {
                    // Advance the world one event. The arena consumes the
                    // finish event's liveness ref and filters stale
                    // finishes from revoked executions itself.
                    if let Some((_, ev)) = engine.pop() {
                        if let Event::TaskFinish { server, task } = ev {
                            if let cloudcoaster::cluster::FinishOutcome::Finished {
                                drained: true,
                                ..
                            } = cluster.on_task_finish(server, task, &mut engine, &mut rec)
                            {
                                cluster.retire(server, engine.now(), &mut rec);
                            }
                        }
                    }
                }
                7 => {
                    let sid = cluster.request_transient(engine.now());
                    cluster.transient_ready(sid, engine.now(), &mut rec);
                    transients.push(sid);
                }
                8 => {
                    if let Some(pos) =
                        (!cluster.transient_pool.is_empty()).then(|| rng.below(cluster.transient_pool.len() as u64) as usize)
                    {
                        let sid = cluster.transient_pool[pos];
                        if cluster.begin_drain(sid) {
                            cluster.retire(sid, engine.now(), &mut rec);
                        }
                    }
                }
                _ => {
                    if let Some(pos) =
                        (!cluster.transient_pool.is_empty()).then(|| rng.below(cluster.transient_pool.len() as u64) as usize)
                    {
                        let sid = cluster.transient_pool[pos];
                        let orphans = cluster.revoke(sid, engine.now(), &mut rec);
                        // Re-place orphans on the first on-demand server.
                        for tid in orphans {
                            if cluster.task(tid).state == TaskState::Queued {
                                let target = cluster.short_reserved[0];
                                cluster.enqueue(tid, target, &mut engine, &mut rec);
                            }
                        }
                    }
                }
            }
            cluster.check_invariants();
        }
        // Drain the world and re-check.
        while let Some((_, ev)) = engine.pop() {
            if let Event::TaskFinish { server, task } = ev {
                if let cloudcoaster::cluster::FinishOutcome::Finished { drained: true, .. } =
                    cluster.on_task_finish(server, task, &mut engine, &mut rec)
                {
                    cluster.retire(server, engine.now(), &mut rec);
                }
            }
        }
        cluster.check_invariants();
        // No task left behind in a live queue.
        for s in &cluster.servers {
            if matches!(s.state, ServerState::Active | ServerState::Draining) {
                for &tid in &s.queue {
                    assert_ne!(
                        cluster.task(tid).state,
                        TaskState::Queued,
                        "live queued task stranded after quiesce"
                    );
                }
            }
        }
    });
}

#[test]
fn prop_steal_preserves_accounting() {
    property("steal accounting", 20, |rng| {
        let mut cluster = Cluster::new(8, 2, QueuePolicy::Fifo);
        let mut engine = Engine::new();
        let mut rec = Recorder::new(1.0);
        // Load one victim with many shorts.
        let victim = cluster.short_reserved[0];
        let n = usize_in(rng, 2, 20);
        for i in 0..n {
            let t = cluster.add_task(JobId(i as u32), 5.0 + rng.f64() * 20.0, false, 0.0);
            cluster.enqueue(t, victim, &mut engine, &mut rec);
        }
        let thief = cluster.short_reserved[1];
        let moved = cluster.steal_short_tasks(victim, thief, usize_in(rng, 1, 8), &mut engine, &mut rec);
        assert!(moved <= n.saturating_sub(1)); // running task not stolen
        cluster.check_invariants();
        // Everything still completes.
        while let Some((_, ev)) = engine.pop() {
            if let Event::TaskFinish { server, task } = ev {
                cluster.on_task_finish(server, task, &mut engine, &mut rec);
            }
        }
        assert_eq!(rec.tasks_finished as usize, n);
    });
}

#[test]
fn prop_simulation_is_deterministic() {
    property("determinism", 8, |rng| {
        let w = random_workload(rng, 500.0);
        let cfg = random_cfg(rng, true);
        let run = || {
            let mut s = Hybrid::cloudcoaster(2.0);
            simulate(&w, &mut s, &cfg)
        };
        let a = run();
        let b = run();
        assert_eq!(a.events, b.events);
        assert_eq!(a.rec.short_delays, b.rec.short_delays);
        assert_eq!(a.rec.transients_requested, b.rec.transients_requested);
    });
}
