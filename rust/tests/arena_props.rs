//! Generational arena stress tests (tasks AND servers): randomized
//! interleavings of enqueue (with §3.3 duplicate copies), finish,
//! steal, revoke, drain and provision, asserting that
//!
//! * a recycled slot is **never resurrected** — every task finishes at
//!   most once, stale task/server handles stay stale forever, and a
//!   stale finish event from a revoked execution resolves to `Stale`;
//! * both arenas stay bounded by their peak-active counts (the
//!   O(active) memory guarantee), while with recycling off they grow
//!   with totals (tasks ever created / transients ever requested);
//! * recycling is **observationally invisible**: the same op sequence
//!   against recycling and non-recycling clusters — any combination of
//!   the task and server toggles — produces the exact same delays,
//!   finish counts, stale-copy counts, `peak_resident_tasks` and
//!   `peak_resident_servers`. Only slot counts may differ;
//! * the struct-of-arrays hot-field mirror tracks the `Server` structs
//!   bitwise through every transition (pinned per step through the
//!   dense accessors here and through the raw arrays by
//!   `check_invariants`), and the SoA read mode is itself
//!   observationally invisible.
//!
//! Every operation selects its targets through the *pools* (general /
//! short-reserved / transient, in ready order), never through raw slot
//! indices — pool contents and order are recycling-mode independent,
//! so the same seed drives the identical op sequence in every mode.

use std::collections::HashMap;

use cloudcoaster::cluster::{Cluster, FinishOutcome, QueuePolicy, TaskState};
use cloudcoaster::metrics::Recorder;
use cloudcoaster::sim::{Engine, Event, Rng};
use cloudcoaster::testkit::{property, usize_in};
use cloudcoaster::util::{JobId, ServerRef, TaskRef};

/// Everything observable a driver run produces (minus slot counts, which
/// legitimately differ between arena modes).
#[derive(Debug, PartialEq)]
struct RunObservables {
    tasks_finished: u64,
    stale_copies_skipped: u64,
    tasks_rescheduled: u64,
    transients_requested: u64,
    transients_revoked: u64,
    short_delays: Vec<f64>,
    peak_resident_tasks: usize,
    peak_resident_servers: usize,
    end_time_bits: u64,
}

/// Slot counts, which are exactly what the modes are allowed to change.
#[derive(Debug, Clone, Copy)]
struct SlotCounts {
    task_slots: usize,
    server_slots: usize,
}

/// A live server the driver may target, chosen by pool position (mode
/// independent): index into general ++ short_reserved ++ transient_pool.
fn pool_member(cluster: &Cluster, k: usize) -> ServerRef {
    let g = cluster.general.len();
    let s = cluster.short_reserved.len();
    if k < g {
        cluster.general[k]
    } else if k < g + s {
        cluster.short_reserved[k - g]
    } else {
        cluster.transient_pool[k - g - s]
    }
}

fn pool_size(cluster: &Cluster) -> usize {
    cluster.general.len() + cluster.short_reserved.len() + cluster.transient_pool.len()
}

/// Drive a random but fully seed-determined interleaving of cluster ops.
/// `soa` selects the hot-field read path (dense struct-of-arrays mirror
/// vs. reference struct reads) — observables must be identical.
fn drive(
    seed: u64,
    recycle_tasks: bool,
    recycle_servers: bool,
    soa: bool,
    steps: usize,
) -> (RunObservables, SlotCounts) {
    let mut rng = Rng::new(seed);
    let mut cluster = Cluster::new(6, 3, QueuePolicy::Fifo);
    cluster.set_task_recycling(recycle_tasks);
    cluster.set_server_recycling(recycle_servers);
    cluster.set_soa_hot_fields(soa);
    let mut engine = Engine::new();
    // Exact delay backend: observables compare the raw sample sequence.
    let mut rec = Recorder::new_exact(2.0);

    // Per-ref bookkeeping: how many times each issued handle finished,
    // and every transient handle ever issued (for resurrection checks).
    let mut finish_counts: HashMap<TaskRef, u32> = HashMap::new();
    let mut issued: Vec<TaskRef> = Vec::new();
    let mut leased: Vec<ServerRef> = Vec::new();

    let mut process_finish = |cluster: &mut Cluster,
                              engine: &mut Engine,
                              rec: &mut Recorder,
                              finish_counts: &mut HashMap<TaskRef, u32>,
                              server: ServerRef,
                              task: TaskRef| {
        match cluster.on_task_finish(server, task, engine, rec) {
            FinishOutcome::Stale => {}
            FinishOutcome::Finished { drained, .. } => {
                let n = finish_counts.entry(task).or_insert(0);
                *n += 1;
                assert_eq!(*n, 1, "task {task:?} finished more than once (resurrected slot)");
                if drained {
                    cluster.retire(server, engine.now(), rec);
                }
            }
        }
    };

    for step in 0..steps {
        match rng.below(12) {
            // Enqueue a fresh short/long task on a random accepting pool
            // member; sometimes mirror a §3.3 duplicate copy onto an
            // on-demand short server.
            0..=5 => {
                let sid = pool_member(&cluster, rng.below(pool_size(&cluster) as u64) as usize);
                let is_long = cluster.general.contains(&sid) && rng.f64() < 0.25;
                let dur = 0.5 + rng.f64() * 40.0;
                let t = cluster.add_task(JobId(step as u32), dur, is_long, engine.now());
                issued.push(t);
                cluster.enqueue(t, sid, &mut engine, &mut rec);
                if !is_long && rng.f64() < 0.35 && cluster.task(t).state == TaskState::Queued {
                    if let Some(od) = cluster.least_loaded_short_reserved() {
                        if od != sid {
                            cluster.enqueue(t, od, &mut engine, &mut rec);
                        }
                    }
                }
            }
            // Advance one event.
            6..=7 => {
                if let Some((_, ev)) = engine.pop() {
                    if let Event::TaskFinish { server, task } = ev {
                        process_finish(
                            &mut cluster,
                            &mut engine,
                            &mut rec,
                            &mut finish_counts,
                            server,
                            task,
                        );
                    }
                }
            }
            // Steal between random live pool members.
            8 => {
                let n = pool_size(&cluster) as u64;
                let victim = pool_member(&cluster, rng.below(n) as usize);
                let thief = pool_member(&cluster, rng.below(n) as usize);
                let batch = usize_in(&mut rng, 1, 4);
                cluster.steal_short_tasks(victim, thief, batch, &mut engine, &mut rec);
            }
            // Provision a transient.
            9 => {
                if cluster.transient_pool.len() < 6 {
                    let sid = cluster.request_transient(engine.now());
                    rec.transients_requested += 1;
                    leased.push(sid);
                    cluster.transient_ready(sid, engine.now(), &mut rec);
                }
            }
            // Graceful drain.
            10 => {
                if !cluster.transient_pool.is_empty() {
                    let k = rng.below(cluster.transient_pool.len() as u64) as usize;
                    let sid = cluster.transient_pool[k];
                    if cluster.begin_drain(sid) {
                        cluster.retire(sid, engine.now(), &mut rec);
                    }
                }
            }
            // Revoke (the stale-finish / shadow-copy / stale-handle
            // gauntlet); re-place orphans like the default scheduler
            // fallback.
            _ => {
                if !cluster.transient_pool.is_empty() {
                    let k = rng.below(cluster.transient_pool.len() as u64) as usize;
                    let sid = cluster.transient_pool[k];
                    let orphans = cluster.revoke(sid, engine.now(), &mut rec);
                    // The revoked handle must be dead immediately with
                    // recycling on; with it off the payload is Retired.
                    match cluster.get_server(sid) {
                        None => assert!(recycle_servers, "slot released with recycling off"),
                        Some(s) => {
                            assert!(!recycle_servers, "revoked slot still live with recycling on");
                            assert_eq!(s.state, cloudcoaster::cluster::ServerState::Retired);
                        }
                    }
                    for tid in orphans {
                        rec.tasks_rescheduled += 1;
                        let target = cluster
                            .least_loaded_short_reserved()
                            .unwrap_or_else(|| cluster.general[0]);
                        cluster.enqueue(tid, target, &mut engine, &mut rec);
                    }
                }
            }
        }
        cluster.check_invariants();
        // Dense-mirror pin, through the *accessors* (whichever read mode
        // is active must agree with a direct struct read for every live
        // pool member; `check_invariants` above already pins the raw
        // arrays bitwise for every slot, freed included).
        for i in 0..pool_size(&cluster) {
            let sid = pool_member(&cluster, i);
            let s = cluster.server(sid);
            let (est, longs, acc, queued, transient) = (
                s.est_work.to_bits(),
                s.long_tasks > 0,
                s.accepting(),
                !s.queue.is_empty(),
                s.kind == cloudcoaster::cluster::ServerKind::Transient,
            );
            assert_eq!(cluster.est_work_of(sid).to_bits(), est, "est_work mirror diverged");
            assert_eq!(cluster.has_long(sid), longs, "has_long mirror diverged");
            assert_eq!(cluster.is_accepting(sid), acc, "accepting mirror diverged");
            assert_eq!(cluster.has_queued(sid), queued, "has_queued mirror diverged");
            assert_eq!(cluster.is_transient(sid), transient, "is_transient mirror diverged");
        }
        if recycle_tasks {
            // The memory headline: the arena never holds more slots than
            // the peak number of simultaneously live tasks.
            assert!(
                cluster.task_slots() <= cluster.peak_resident_tasks(),
                "task arena grew past peak-active: {} slots vs peak {}",
                cluster.task_slots(),
                cluster.peak_resident_tasks()
            );
        }
        if recycle_servers {
            assert!(
                cluster.server_slots() <= cluster.peak_resident_servers(),
                "server arena grew past peak-active: {} slots vs peak {}",
                cluster.server_slots(),
                cluster.peak_resident_servers()
            );
        }
    }

    // Quiesce.
    while let Some((_, ev)) = engine.pop() {
        if let Event::TaskFinish { server, task } = ev {
            process_finish(&mut cluster, &mut engine, &mut rec, &mut finish_counts, server, task);
        }
    }
    cluster.check_invariants();

    // Conservation: every issued task finished exactly once — revocation,
    // duplication and stealing never lose or duplicate work. A handle may
    // have been re-used (recycling), so count by handle identity.
    assert_eq!(
        finish_counts.values().sum::<u32>() as usize,
        issued.len(),
        "finish count != issued tasks"
    );
    assert_eq!(rec.tasks_finished as usize, issued.len());
    if recycle_tasks {
        // Everything settled at quiescence -> every slot released, and no
        // stale handle dereferences.
        assert_eq!(cluster.resident_tasks(), 0, "slots still pinned after quiesce");
        for &r in &issued {
            assert!(
                cluster.get_task(r).is_none(),
                "released handle {r:?} still (or again) dereferences — resurrection"
            );
        }
        assert_eq!(
            cluster.task_slots(),
            cluster.peak_resident_tasks(),
            "slot count != peak-active"
        );
    }
    if recycle_servers {
        // Retired leases released their slots; handles of *currently
        // Active* transients still resolve, all others are dead.
        for &sid in &leased {
            if let Some(s) = cluster.get_server(sid) {
                assert_ne!(
                    s.state,
                    cloudcoaster::cluster::ServerState::Retired,
                    "retired lease {sid:?} still dereferences — server resurrection"
                );
            }
        }
        assert_eq!(
            cluster.server_slots(),
            cluster.peak_resident_servers(),
            "server slot count != peak-active"
        );
    }

    (
        RunObservables {
            tasks_finished: rec.tasks_finished,
            stale_copies_skipped: rec.stale_copies_skipped,
            tasks_rescheduled: rec.tasks_rescheduled,
            transients_requested: rec.transients_requested,
            transients_revoked: rec.transients_revoked,
            short_delays: rec.short_delays.samples().expect("exact backend").to_vec(),
            peak_resident_tasks: cluster.peak_resident_tasks(),
            peak_resident_servers: cluster.peak_resident_servers(),
            end_time_bits: engine.now().to_bits(),
        },
        SlotCounts { task_slots: cluster.task_slots(), server_slots: cluster.server_slots() },
    )
}

#[test]
fn arena_stress_no_resurrection_and_bounded_slots() {
    property("arena stress", 30, |rng| {
        let seed = rng.next_u64();
        drive(seed, true, true, true, 300);
    });
}

#[test]
fn arena_recycling_is_observationally_invisible() {
    // Same seed-determined op sequence across all four recycling-mode
    // combinations: every simulation observable — including both peaks,
    // whose accounting is mode-independent — must match bit-exactly.
    // Only the slot counts may differ (that's the point of the arenas).
    property("arena mode equivalence", 10, |rng| {
        let seed = rng.next_u64();
        let (both, slots_both) = drive(seed, true, true, true, 250);
        let (neither, slots_neither) = drive(seed, false, false, true, 250);
        let (tasks_only, _) = drive(seed, true, false, true, 250);
        let (servers_only, _) = drive(seed, false, true, true, 250);
        assert_eq!(both, neither, "recycling changed an observable");
        assert_eq!(both, tasks_only, "task recycling alone changed an observable");
        assert_eq!(both, servers_only, "server recycling alone changed an observable");
        assert!(
            slots_both.task_slots <= slots_neither.task_slots,
            "task recycling used more slots ({} vs {})",
            slots_both.task_slots,
            slots_neither.task_slots
        );
        assert!(
            slots_both.server_slots <= slots_neither.server_slots,
            "server recycling used more slots ({} vs {})",
            slots_both.server_slots,
            slots_neither.server_slots
        );
    });
}

#[test]
fn soa_read_mode_is_observationally_invisible() {
    // Same seed-determined op sequence with hot fields served from the
    // dense SoA mirror vs. read back through the `Server` structs:
    // every observable must match bit-exactly — the mirror is
    // maintained unconditionally, the toggle only picks the read path.
    // (The per-step mirror pin inside `drive` runs in both modes, so
    // the dense arrays are checked against the structs throughout.)
    property("soa mode equivalence", 10, |rng| {
        let seed = rng.next_u64();
        let (dense, slots_dense) = drive(seed, true, true, true, 250);
        let (structs, slots_structs) = drive(seed, true, true, false, 250);
        assert_eq!(dense, structs, "SoA read path changed an observable");
        assert_eq!(slots_dense.task_slots, slots_structs.task_slots);
        assert_eq!(slots_dense.server_slots, slots_structs.server_slots);
    });
}

#[test]
fn generations_distinguish_slot_reuse() {
    // Deterministic mini-case: run one task to completion, reuse the
    // slot, and check the old handle stays dead across the reuse.
    let mut cluster = Cluster::new(2, 1, QueuePolicy::Fifo);
    let mut engine = Engine::new();
    let mut rec = Recorder::new(1.0);
    let a = cluster.add_task(JobId(0), 5.0, false, 0.0);
    cluster.enqueue(a, cluster.general[0], &mut engine, &mut rec);
    let (_, ev) = engine.pop().unwrap();
    if let Event::TaskFinish { server, task } = ev {
        assert!(matches!(
            cluster.on_task_finish(server, task, &mut engine, &mut rec),
            FinishOutcome::Finished { .. }
        ));
    }
    assert!(cluster.get_task(a).is_none(), "slot not released after full settle");
    let b = cluster.add_task(JobId(1), 5.0, false, 10.0);
    assert_eq!(b.slot, a.slot, "free slot not reused");
    assert_ne!(b.gen, a.gen, "generation not bumped on reuse");
    assert!(cluster.get_task(a).is_none(), "stale handle resurrected by reuse");
    assert!(cluster.get_task(b).is_some());
    cluster.check_invariants();
}

#[test]
fn server_generations_distinguish_slot_reuse() {
    // The server twin: lease, revoke, re-lease — the old handle must
    // stay dead across the reuse, and the pending stale lifecycle
    // events must not touch the new tenant.
    let mut cluster = Cluster::new(2, 1, QueuePolicy::Fifo);
    let mut engine = Engine::new();
    let mut rec = Recorder::new(1.0);
    let first = cluster.request_transient(0.0);
    cluster.transient_ready(first, 0.0, &mut rec);
    // A task mid-run on the lease: its finish event will pop stale.
    let t = cluster.add_task(JobId(0), 30.0, false, 0.0);
    cluster.enqueue(t, first, &mut engine, &mut rec);
    let orphans = cluster.revoke(first, 5.0, &mut rec);
    assert_eq!(orphans, vec![t]);
    assert!(cluster.get_server(first).is_none(), "revoked slot still dereferences");
    // Re-lease: same arena slot, new generation.
    let second = cluster.request_transient(6.0);
    assert_eq!(second.slot, first.slot);
    assert_ne!(second.gen, first.gen);
    cluster.transient_ready(second, 6.0, &mut rec);
    // Re-place the orphan on the new tenant; drain everything. The
    // stale finish (addressed to `first`) must resolve Stale without
    // touching `second`, and the task finishes exactly once.
    cluster.enqueue(t, second, &mut engine, &mut rec);
    let (mut stale, mut finished) = (0, 0);
    while let Some((_, ev)) = engine.pop() {
        if let Event::TaskFinish { server, task } = ev {
            match cluster.on_task_finish(server, task, &mut engine, &mut rec) {
                FinishOutcome::Stale => stale += 1,
                FinishOutcome::Finished { drained, .. } => {
                    finished += 1;
                    if drained {
                        cluster.retire(server, engine.now(), &mut rec);
                    }
                }
            }
        }
    }
    assert_eq!((stale, finished), (1, 1));
    assert!(cluster.get_server(first).is_none());
    assert!(cluster.get_server(second).is_some());
    cluster.check_invariants();
}
