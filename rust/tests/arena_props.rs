//! Generational task-arena stress tests: randomized interleavings of
//! enqueue (with §3.3 duplicate copies), finish, steal, revoke, drain
//! and provision, asserting that
//!
//! * a recycled slot is **never resurrected** — every task finishes at
//!   most once, stale handles stay stale forever, and a stale finish
//!   event from a revoked execution resolves to `Stale`;
//! * the arena's slot count stays bounded by peak-active tasks (the
//!   O(active) memory guarantee), while with recycling off it grows with
//!   total tasks;
//! * recycling is **observationally invisible**: the same op sequence
//!   against a recycling and a non-recycling cluster produces the exact
//!   same delays, finish counts, stale-copy counts and
//!   `peak_resident_tasks`.

use std::collections::HashMap;

use cloudcoaster::cluster::{Cluster, FinishOutcome, QueuePolicy, TaskState};
use cloudcoaster::metrics::Recorder;
use cloudcoaster::sim::{Engine, Event, Rng};
use cloudcoaster::testkit::{property, usize_in};
use cloudcoaster::util::{JobId, ServerId, TaskRef};

/// Everything observable a driver run produces (minus slot counts, which
/// legitimately differ between arena modes).
#[derive(Debug, PartialEq)]
struct RunObservables {
    tasks_finished: u64,
    stale_copies_skipped: u64,
    tasks_rescheduled: u64,
    short_delays: Vec<f64>,
    peak_resident_tasks: usize,
    end_time_bits: u64,
}

/// Drive a random but fully seed-determined interleaving of cluster ops.
/// Returns the observables plus the final slot count.
fn drive(seed: u64, recycle: bool, steps: usize) -> (RunObservables, usize) {
    let mut rng = Rng::new(seed);
    let mut cluster = Cluster::new(6, 3, QueuePolicy::Fifo);
    cluster.set_task_recycling(recycle);
    let mut engine = Engine::new();
    let mut rec = Recorder::new(2.0);

    // Per-ref bookkeeping: how many times each issued handle finished.
    let mut finish_counts: HashMap<TaskRef, u32> = HashMap::new();
    let mut issued: Vec<TaskRef> = Vec::new();

    let mut process_finish = |cluster: &mut Cluster,
                              engine: &mut Engine,
                              rec: &mut Recorder,
                              finish_counts: &mut HashMap<TaskRef, u32>,
                              server: ServerId,
                              task: TaskRef| {
        match cluster.on_task_finish(server, task, engine, rec) {
            FinishOutcome::Stale => {}
            FinishOutcome::Finished { drained, .. } => {
                let n = finish_counts.entry(task).or_insert(0);
                *n += 1;
                assert_eq!(*n, 1, "task {task:?} finished more than once (resurrected slot)");
                if drained {
                    cluster.retire(server, engine.now(), rec);
                }
            }
        }
    };

    for step in 0..steps {
        match rng.below(12) {
            // Enqueue a fresh short/long task; sometimes mirror a §3.3
            // duplicate copy onto an on-demand short server.
            0..=5 => {
                let accepting: Vec<ServerId> = cluster
                    .servers
                    .iter()
                    .filter(|s| s.accepting())
                    .map(|s| s.id)
                    .collect();
                let sid = accepting[rng.below(accepting.len() as u64) as usize];
                let is_long = cluster.general.contains(&sid) && rng.f64() < 0.25;
                let dur = 0.5 + rng.f64() * 40.0;
                let t = cluster.add_task(JobId(step as u32), dur, is_long, engine.now());
                issued.push(t);
                cluster.enqueue(t, sid, &mut engine, &mut rec);
                if !is_long && rng.f64() < 0.35 && cluster.task(t).state == TaskState::Queued {
                    if let Some(od) = cluster.least_loaded_short_reserved() {
                        if od != sid {
                            cluster.enqueue(t, od, &mut engine, &mut rec);
                        }
                    }
                }
            }
            // Advance one event.
            6..=7 => {
                if let Some((_, ev)) = engine.pop() {
                    if let Event::TaskFinish { server, task } = ev {
                        process_finish(
                            &mut cluster,
                            &mut engine,
                            &mut rec,
                            &mut finish_counts,
                            server,
                            task,
                        );
                    }
                }
            }
            // Steal between random servers.
            8 => {
                let n = cluster.servers.len() as u64;
                let victim = ServerId(rng.below(n) as u32);
                let thief = ServerId(rng.below(n) as u32);
                if cluster.server(victim).state != cloudcoaster::cluster::ServerState::Retired
                    && cluster.server(victim).state
                        != cloudcoaster::cluster::ServerState::Provisioning
                {
                    let batch = usize_in(&mut rng, 1, 4);
                    cluster.steal_short_tasks(victim, thief, batch, &mut engine, &mut rec);
                }
            }
            // Provision a transient.
            9 => {
                if cluster.transient_pool.len() < 6 {
                    let sid = cluster.request_transient(engine.now());
                    cluster.transient_ready(sid, engine.now(), &mut rec);
                }
            }
            // Graceful drain.
            10 => {
                if !cluster.transient_pool.is_empty() {
                    let k = rng.below(cluster.transient_pool.len() as u64) as usize;
                    let sid = cluster.transient_pool[k];
                    if cluster.begin_drain(sid) {
                        cluster.retire(sid, engine.now(), &mut rec);
                    }
                }
            }
            // Revoke (the stale-finish / shadow-copy gauntlet); re-place
            // orphans like the default scheduler fallback.
            _ => {
                if !cluster.transient_pool.is_empty() {
                    let k = rng.below(cluster.transient_pool.len() as u64) as usize;
                    let sid = cluster.transient_pool[k];
                    let orphans = cluster.revoke(sid, engine.now(), &mut rec);
                    for tid in orphans {
                        rec.tasks_rescheduled += 1;
                        let target = cluster
                            .least_loaded_short_reserved()
                            .unwrap_or_else(|| cluster.general[0]);
                        cluster.enqueue(tid, target, &mut engine, &mut rec);
                    }
                }
            }
        }
        cluster.check_invariants();
        if recycle {
            // The memory headline: the arena never holds more slots than
            // the peak number of simultaneously live tasks.
            assert!(
                cluster.task_slots() <= cluster.peak_resident_tasks(),
                "arena grew past peak-active: {} slots vs peak {}",
                cluster.task_slots(),
                cluster.peak_resident_tasks()
            );
        }
    }

    // Quiesce.
    while let Some((_, ev)) = engine.pop() {
        if let Event::TaskFinish { server, task } = ev {
            process_finish(&mut cluster, &mut engine, &mut rec, &mut finish_counts, server, task);
        }
    }
    cluster.check_invariants();

    // Conservation: every issued task finished exactly once — revocation,
    // duplication and stealing never lose or duplicate work. A handle may
    // have been re-used (recycling), so count by handle identity.
    assert_eq!(
        finish_counts.values().sum::<u32>() as usize,
        issued.len(),
        "finish count != issued tasks"
    );
    assert_eq!(rec.tasks_finished as usize, issued.len());
    if recycle {
        // Everything settled at quiescence -> every slot released, and no
        // stale handle dereferences.
        assert_eq!(cluster.resident_tasks(), 0, "slots still pinned after quiesce");
        for &r in &issued {
            assert!(
                cluster.get_task(r).is_none(),
                "released handle {r:?} still (or again) dereferences — resurrection"
            );
        }
        assert_eq!(
            cluster.task_slots(),
            cluster.peak_resident_tasks(),
            "slot count != peak-active"
        );
    }

    (
        RunObservables {
            tasks_finished: rec.tasks_finished,
            stale_copies_skipped: rec.stale_copies_skipped,
            tasks_rescheduled: rec.tasks_rescheduled,
            short_delays: rec.short_delays.as_slice().to_vec(),
            peak_resident_tasks: cluster.peak_resident_tasks(),
            end_time_bits: engine.now().to_bits(),
        },
        cluster.task_slots(),
    )
}

#[test]
fn arena_stress_no_resurrection_and_bounded_slots() {
    property("arena stress", 30, |rng| {
        let seed = rng.next_u64();
        drive(seed, true, 300);
    });
}

#[test]
fn arena_recycling_is_observationally_invisible() {
    // Same seed-determined op sequence, recycling on vs off: every
    // simulation observable — including peak_resident_tasks, whose
    // liveness accounting is mode-independent — must match bit-exactly.
    // Only the slot count may differ (that's the point of the arena).
    property("arena mode equivalence", 12, |rng| {
        let seed = rng.next_u64();
        let (with, slots_with) = drive(seed, true, 250);
        let (without, slots_without) = drive(seed, false, 250);
        assert_eq!(with, without, "recycling changed an observable");
        assert!(
            slots_with <= slots_without,
            "recycling used more slots ({slots_with}) than append-only ({slots_without})"
        );
    });
}

#[test]
fn generations_distinguish_slot_reuse() {
    // Deterministic mini-case: run one task to completion, reuse the
    // slot, and check the old handle stays dead across the reuse.
    let mut cluster = Cluster::new(2, 1, QueuePolicy::Fifo);
    let mut engine = Engine::new();
    let mut rec = Recorder::new(1.0);
    let a = cluster.add_task(JobId(0), 5.0, false, 0.0);
    cluster.enqueue(a, ServerId(0), &mut engine, &mut rec);
    let (_, ev) = engine.pop().unwrap();
    if let Event::TaskFinish { server, task } = ev {
        assert!(matches!(
            cluster.on_task_finish(server, task, &mut engine, &mut rec),
            FinishOutcome::Finished { .. }
        ));
    }
    assert!(cluster.get_task(a).is_none(), "slot not released after full settle");
    let b = cluster.add_task(JobId(1), 5.0, false, 10.0);
    assert_eq!(b.slot, a.slot, "free slot not reused");
    assert_ne!(b.gen, a.gen, "generation not bumped on reuse");
    assert!(cluster.get_task(a).is_none(), "stale handle resurrected by reuse");
    assert!(cluster.get_task(b).is_some());
    cluster.check_invariants();
}
