//! Golden equivalence: the composable `World` runner must reproduce the
//! pre-refactor monolithic `simulate_with` loop **bit-exactly** — same
//! event count, same end time, same per-task delay sequences — for both
//! the Eagle baseline and CloudCoaster (manager + stealing + revocation
//! paths) on fixed-seed workloads.
//!
//! The oracle below (`legacy_simulate`) is a line-faithful copy of the
//! monolithic event loop the refactor decomposed (match-dispatch over
//! events, inline stealing, in-loop manager calls), driven through the
//! same public cluster/scheduler/manager APIs. Any divergence in event
//! ordering, RNG stream usage or bookkeeping introduced by the
//! `World`/`Component` decomposition shows up here as a hard failure.

use cloudcoaster::cluster::{Cluster, FinishOutcome, ServerState};
use cloudcoaster::coordinator::runner::{simulate, SimConfig};
use cloudcoaster::metrics::Recorder;
use cloudcoaster::sched::{Hybrid, SchedCtx, Scheduler};
use cloudcoaster::sim::{Engine, Event, Rng};
use cloudcoaster::trace::synth::{yahoo_like, YahooLikeParams};
use cloudcoaster::trace::Workload;
use cloudcoaster::util::{RNG_MARKET, RNG_SCHED};
use cloudcoaster::transient::{Budget, ManagerConfig, TransientManager};
use cloudcoaster::util::{JobId, TaskRef, Time};

/// What the oracle produces for comparison. Delay populations are the
/// whole `DelayDist` (histogram state compares bit-exactly: bucket
/// counts, push-order sum, min/max).
struct LegacyResult {
    end_time: Time,
    events: u64,
    short_delays: cloudcoaster::metrics::DelayDist,
    long_delays: cloudcoaster::metrics::DelayDist,
    tasks_finished: u64,
    transients_requested: u64,
    manager_stats: Option<(u64, u64, u64)>,
}

/// Verbatim port of the pre-refactor steal helper.
fn legacy_try_steal(
    cluster: &mut Cluster,
    thief: cloudcoaster::util::ServerRef,
    cfg: &SimConfig,
    rng: &mut Rng,
    engine: &mut Engine,
    rec: &mut Recorder,
) {
    for probe in 0..cfg.steal_probes {
        let victim = if probe % 2 == 0 {
            let shorts = cluster.short_reserved.len() + cluster.transient_pool.len();
            if shorts == 0 {
                continue;
            }
            let k = rng.below(shorts as u64) as usize;
            if k < cluster.short_reserved.len() {
                cluster.short_reserved[k]
            } else {
                cluster.transient_pool[k - cluster.short_reserved.len()]
            }
        } else {
            cluster.general[rng.below(cluster.general.len() as u64) as usize]
        };
        if cluster.server(victim).queue.is_empty() {
            continue;
        }
        if cluster.steal_short_tasks(victim, thief, cfg.steal_batch, engine, rec) > 0 {
            return;
        }
    }
}

/// Verbatim port of the pre-refactor monolithic event loop (reactive
/// path; the golden configs don't use predictive resizing).
fn legacy_simulate(
    workload: &Workload,
    scheduler: &mut dyn Scheduler,
    cfg: &SimConfig,
) -> LegacyResult {
    assert!(
        !cfg.manager.as_ref().map(|m| m.predictive).unwrap_or(false),
        "oracle covers the reactive path only"
    );
    let r = cfg.manager.as_ref().map(|m| m.budget.r).unwrap_or(1.0);
    let mut cluster = Cluster::new(cfg.n_general, cfg.n_short_reserved, cfg.queue_policy);
    // Honor the cfg's arena/backend knobs exactly like the runner does,
    // so the oracle stays cfg-driven if a golden flips a reference mode.
    cluster.set_task_recycling(cfg.recycle_task_slots);
    cluster.set_server_recycling(cfg.recycle_server_slots);
    let mut engine = Engine::new();
    let mut rec = Recorder::with_backend(r, cfg.exact_delay_samples);
    let mut root_rng = Rng::new(cfg.seed);
    let mut sched_rng = root_rng.fork(RNG_SCHED); // probe sampling stream
    let mut manager = cfg
        .manager
        .clone()
        .map(|m| TransientManager::new(m, root_rng.fork(RNG_MARKET)));

    let mut job_remaining: Vec<u32> =
        workload.jobs.iter().map(|j| j.num_tasks() as u32).collect();
    let mut outstanding_tasks: u64 = workload.num_tasks() as u64;
    let mut next_job = 0usize;
    let mut task_ids: Vec<TaskRef> = Vec::new();

    if !workload.jobs.is_empty() {
        engine.schedule(workload.jobs[0].arrival, Event::JobArrival(JobId(0)));
        engine.schedule(cfg.snapshot_interval, Event::Snapshot);
    }

    while let Some((now, event)) = engine.pop() {
        let mut long_event = false;
        match event {
            Event::JobArrival(jid) => {
                let job = &workload.jobs[jid.index()];
                task_ids.clear();
                for &d in &job.task_durations {
                    task_ids.push(cluster.add_task(job.id, d, job.is_long, now));
                }
                let mut ctx = SchedCtx {
                    cluster: &mut cluster,
                    engine: &mut engine,
                    rec: &mut rec,
                    rng: &mut sched_rng,
                };
                scheduler.place_job(job, &task_ids, &mut ctx);
                long_event = job.is_long;
                next_job = jid.index() + 1;
                if next_job < workload.jobs.len() {
                    engine.schedule(
                        workload.jobs[next_job].arrival,
                        Event::JobArrival(JobId(next_job as u32)),
                    );
                }
            }
            Event::TaskFinish { server, task } => {
                // The arena consumes the event's liveness ref and
                // reports staleness itself; completion fields come out
                // of the outcome, never through the (possibly recycled)
                // TaskRef — matching the pre-arena stale filter exactly.
                let (is_long, jid, drained) =
                    match cluster.on_task_finish(server, task, &mut engine, &mut rec) {
                        FinishOutcome::Stale => continue,
                        FinishOutcome::Finished { job, is_long, drained } => {
                            (is_long, job, drained)
                        }
                    };
                if drained {
                    cluster.retire(server, now, &mut rec);
                } else if cfg.steal_probes > 0
                    && cluster.server(server).is_idle()
                    && cluster.server(server).accepting()
                {
                    legacy_try_steal(&mut cluster, server, cfg, &mut sched_rng, &mut engine, &mut rec);
                }
                outstanding_tasks -= 1;
                let rem = &mut job_remaining[jid.index()];
                *rem -= 1;
                if *rem == 0 {
                    let job = &workload.jobs[jid.index()];
                    rec.job_finished(job.is_long, now - job.arrival);
                }
                long_event = is_long;
            }
            Event::TransientReady(sid) => {
                if let Some(mgr) = manager.as_mut() {
                    mgr.on_ready(sid, &mut cluster, &engine, &mut rec);
                }
            }
            Event::RevocationWarning(sid) => {
                if let Some(mgr) = manager.as_mut() {
                    mgr.on_warning(sid, &mut cluster, &engine, &mut rec);
                }
            }
            Event::Revoked(sid) => {
                // Generation-checked, like the World core: a stale
                // Revoked (server already drained/retired, slot maybe
                // recycled) must not touch the slot's next tenant.
                let state = cluster.get_server(sid).map(|s| s.state);
                if matches!(state, Some(ServerState::Active | ServerState::Draining)) {
                    let orphans = cluster.revoke(sid, now, &mut rec);
                    if !orphans.is_empty() {
                        let mut ctx = SchedCtx {
                            cluster: &mut cluster,
                            engine: &mut engine,
                            rec: &mut rec,
                            rng: &mut sched_rng,
                        };
                        scheduler.replace_orphans(&orphans, &mut ctx);
                    }
                }
            }
            Event::DrainComplete(sid) => {
                let ok = cluster
                    .get_server(sid)
                    .is_some_and(|s| s.state == ServerState::Draining && s.is_idle());
                if ok {
                    cluster.retire(sid, now, &mut rec);
                }
            }
            Event::Snapshot => {
                let lr = cluster.long_load_ratio();
                rec.snapshot(now, lr, cluster.transient_pool.len() as f64);
                if outstanding_tasks > 0 || next_job < workload.jobs.len() {
                    engine.schedule_after(cfg.snapshot_interval, Event::Snapshot);
                }
            }
        }
        if long_event {
            if let Some(mgr) = manager.as_mut() {
                mgr.maybe_resize(&mut cluster, &mut engine, &mut rec);
            }
        }
    }

    let end_time = engine.now();
    let live: Vec<_> = cluster
        .servers
        .iter()
        .filter(|s| {
            s.kind == cloudcoaster::cluster::ServerKind::Transient
                && matches!(s.state, ServerState::Active | ServerState::Draining)
        })
        .map(|s| s.id)
        .collect();
    for sid in live {
        cluster.retire(sid, end_time, &mut rec);
    }
    assert_eq!(outstanding_tasks, 0, "oracle lost tasks");

    LegacyResult {
        end_time,
        events: engine.processed(),
        short_delays: rec.short_delays.clone(),
        long_delays: rec.long_delays.clone(),
        tasks_finished: rec.tasks_finished,
        transients_requested: rec.transients_requested,
        manager_stats: manager.map(|m| (m.adds, m.drains, m.failed_requests)),
    }
}

fn golden_workload(seed: u64) -> Workload {
    let mut p = YahooLikeParams::default();
    p.horizon = 4000.0;
    yahoo_like(&p, &mut Rng::new(seed))
}

fn assert_equivalent(workload: &Workload, cfg: &SimConfig, mk: impl Fn() -> Hybrid) {
    let mut legacy_sched = mk();
    let legacy = legacy_simulate(workload, &mut legacy_sched, cfg);
    let mut world_sched = mk();
    let world = simulate(workload, &mut world_sched, cfg);

    assert_eq!(world.events, legacy.events, "event count diverged");
    assert_eq!(world.end_time, legacy.end_time, "end time diverged");
    assert_eq!(world.rec.tasks_finished, legacy.tasks_finished);
    assert_eq!(world.rec.transients_requested, legacy.transients_requested);
    assert_eq!(
        world.rec.short_delays, legacy.short_delays,
        "short-delay distribution diverged"
    );
    assert_eq!(
        world.rec.long_delays, legacy.long_delays,
        "long-delay distribution diverged"
    );
    assert_eq!(world.manager_stats, legacy.manager_stats);
}

#[test]
fn world_matches_legacy_eagle() {
    for seed in [3u64, 9, 17] {
        let w = golden_workload(seed);
        let mut cfg = SimConfig { n_general: 128, n_short_reserved: 8, ..Default::default() };
        cfg.seed = seed;
        assert_equivalent(&w, &cfg, || Hybrid::eagle(2.0));
    }
}

#[test]
fn world_matches_legacy_cloudcoaster() {
    for seed in [3u64, 5] {
        let w = golden_workload(seed);
        let mut cfg = SimConfig { n_general: 128, n_short_reserved: 4, ..Default::default() };
        cfg.seed = seed;
        cfg.manager = Some(ManagerConfig {
            threshold: 0.6,
            ..ManagerConfig::paper(Budget::new(8, 0.5, 3.0))
        });
        assert_equivalent(&w, &cfg, || Hybrid::cloudcoaster(2.0));
    }
}

#[test]
fn world_matches_legacy_under_revocations() {
    let w = golden_workload(5);
    let mut cfg = SimConfig { n_general: 128, n_short_reserved: 4, ..Default::default() };
    cfg.seed = 5;
    let mut mgr = ManagerConfig {
        threshold: 0.5,
        ..ManagerConfig::paper(Budget::new(8, 0.5, 3.0))
    };
    mgr.market.mttf = Some(600.0); // aggressive revocations: orphan path
    cfg.manager = Some(mgr);
    assert_equivalent(&w, &cfg, || Hybrid::cloudcoaster(2.0));
}

#[test]
fn world_matches_legacy_without_stealing() {
    let w = golden_workload(11);
    let mut cfg = SimConfig { n_general: 96, n_short_reserved: 8, ..Default::default() };
    cfg.seed = 11;
    cfg.steal_probes = 0;
    assert_equivalent(&w, &cfg, || Hybrid::eagle(2.0));
}
