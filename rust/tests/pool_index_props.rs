//! Property tests for the per-pool load indexes: under randomized
//! enqueue / finish / steal / provision / drain / revoke sequences, every
//! indexed least-loaded answer must equal the naive linear scan it
//! replaced — including tie-breaking (`Iterator::min_by` first-minimal).

use cloudcoaster::cluster::{Cluster, FinishOutcome, QueuePolicy, TaskState};
use cloudcoaster::metrics::Recorder;
use cloudcoaster::sim::{Engine, Event, Rng};
use cloudcoaster::testkit::{property, usize_in};
use cloudcoaster::util::{JobId, ServerRef};

/// The scan `least_loaded_general` replaced.
fn naive_general(cluster: &Cluster) -> ServerRef {
    *cluster
        .general
        .iter()
        .min_by(|&&a, &&b| cluster.server(a).est_work.total_cmp(&cluster.server(b).est_work))
        .expect("non-empty general partition")
}

/// The scan `least_loaded_short_reserved` replaced (accepting filter is
/// vacuous for on-demand servers but kept for faithfulness).
fn naive_short(cluster: &Cluster) -> Option<ServerRef> {
    cluster
        .short_reserved
        .iter()
        .copied()
        .filter(|&s| cluster.server(s).accepting())
        .min_by(|&a, &b| {
            cluster.server(a).est_work.total_cmp(&cluster.server(b).est_work)
        })
}

/// The scan `transient_drain_victim` replaced: first-minimal
/// `(depth, est_work)` in transient-pool (ready) order. The index's
/// seq-tagged key must reproduce this exactly even while arena and
/// tree slots recycle underneath (pool order == activation order).
fn naive_victim(cluster: &Cluster) -> Option<ServerRef> {
    cluster
        .transient_pool
        .iter()
        .min_by(|&&a, &&b| {
            let sa = cluster.server(a);
            let sb = cluster.server(b);
            (sa.depth(), sa.est_work)
                .partial_cmp(&(sb.depth(), sb.est_work))
                .expect("est_work is never NaN")
        })
        .copied()
}

fn check_index_matches_scans(cluster: &Cluster) {
    assert_eq!(
        cluster.least_loaded_general(),
        naive_general(cluster),
        "general index diverged from linear scan"
    );
    assert_eq!(
        cluster.least_loaded_short_reserved(),
        naive_short(cluster),
        "short index diverged from linear scan"
    );
    assert_eq!(
        cluster.transient_drain_victim(),
        naive_victim(cluster),
        "transient index diverged from linear scan"
    );
}

/// A server the scheduler may legally target (accepting).
fn random_target(cluster: &Cluster, rng: &mut Rng) -> ServerRef {
    let n_candidates =
        cluster.general.len() + cluster.short_reserved.len() + cluster.transient_pool.len();
    let k = rng.below(n_candidates as u64) as usize;
    if k < cluster.general.len() {
        cluster.general[k]
    } else if k < cluster.general.len() + cluster.short_reserved.len() {
        cluster.short_reserved[k - cluster.general.len()]
    } else {
        cluster.transient_pool[k - cluster.general.len() - cluster.short_reserved.len()]
    }
}

#[test]
fn pool_index_matches_naive_scans_under_random_ops() {
    property("pool index == linear scan", 40, |rng| {
        let n_general = usize_in(rng, 2, 24);
        let n_short = usize_in(rng, 1, 6);
        let policy = if rng.f64() < 0.5 {
            QueuePolicy::Fifo
        } else {
            QueuePolicy::Srpt { starvation_limit: 100.0 }
        };
        let mut cluster = Cluster::new(n_general, n_short, policy);
        let mut engine = Engine::new();
        let mut rec = Recorder::new(3.0);

        for _ in 0..250 {
            match rng.below(12) {
                // Place a task (ties are common: many idle servers with
                // est_work 0, exercising first-minimal tie-breaks).
                0..=5 => {
                    let sid = random_target(&cluster, rng);
                    let is_long =
                        cluster.general.contains(&sid) && rng.f64() < 0.3;
                    let dur = if rng.f64() < 0.2 {
                        10.0 // deliberate exact-duration ties
                    } else {
                        0.5 + rng.f64() * 50.0
                    };
                    let t = cluster.add_task(JobId(0), dur, is_long, engine.now());
                    cluster.enqueue(t, sid, &mut engine, &mut rec);
                    // Occasionally mirror a short onto an on-demand
                    // server (the §3.3 duplicate-copy path).
                    if !is_long && rng.f64() < 0.2 {
                        if let Some(od) = cluster.least_loaded_short_reserved() {
                            if od != sid && cluster.task(t).state == TaskState::Queued {
                                cluster.enqueue(t, od, &mut engine, &mut rec);
                            }
                        }
                    }
                }
                // Advance the simulation: process one finish event (the
                // arena filters stale finishes from revocations itself).
                6..=8 => {
                    if let Some((now, ev)) = engine.pop() {
                        if let Event::TaskFinish { server, task } = ev {
                            if let FinishOutcome::Finished { drained: true, .. } =
                                cluster.on_task_finish(server, task, &mut engine, &mut rec)
                            {
                                cluster.retire(server, now, &mut rec);
                            }
                        }
                    }
                }
                // Lease a transient.
                9 => {
                    if cluster.transient_pool.len() < 12 {
                        let sid = cluster.request_transient(engine.now());
                        cluster.transient_ready(sid, engine.now(), &mut rec);
                    }
                }
                // Gracefully drain one.
                10 => {
                    if !cluster.transient_pool.is_empty() {
                        let k = rng.below(cluster.transient_pool.len() as u64) as usize;
                        let sid = cluster.transient_pool[k];
                        if cluster.begin_drain(sid) {
                            cluster.retire(sid, engine.now(), &mut rec);
                        }
                    }
                }
                // Revoke one; re-place any orphans like the default
                // scheduler fallback does.
                _ => {
                    if !cluster.transient_pool.is_empty() {
                        let k = rng.below(cluster.transient_pool.len() as u64) as usize;
                        let sid = cluster.transient_pool[k];
                        let orphans = cluster.revoke(sid, engine.now(), &mut rec);
                        for tid in orphans {
                            let target = cluster
                                .least_loaded_short_reserved()
                                .unwrap_or_else(|| cluster.general[0]);
                            cluster.enqueue(tid, target, &mut engine, &mut rec);
                        }
                    }
                }
            }
            check_index_matches_scans(&cluster);
            cluster.check_invariants();
        }

        // Drain the world to quiescence; the index must stay exact the
        // whole way down.
        while let Some((now, ev)) = engine.pop() {
            if let Event::TaskFinish { server, task } = ev {
                if let FinishOutcome::Finished { drained: true, .. } =
                    cluster.on_task_finish(server, task, &mut engine, &mut rec)
                {
                    cluster.retire(server, now, &mut rec);
                }
            }
            check_index_matches_scans(&cluster);
        }
        cluster.check_invariants();
    });
}
