//! XLA ⇄ native equivalence: the AOT-compiled artifacts (L2 JAX graphs
//! wrapping L1 Pallas kernels, executed via PJRT) must agree with the
//! pure-rust reference engine on randomized inputs. This is the rust-side
//! half of the correctness story (the python side checks Pallas vs jnp).
//!
//! The whole suite is gated on the `xla` feature (the PJRT crate is not
//! vendored in this offline build); tests are additionally skipped (pass
//! trivially) when `artifacts/` has not been built — run `make artifacts`
//! first for full coverage.
#![cfg(feature = "xla")]

use cloudcoaster::coordinator::report::artifacts_dir;
use cloudcoaster::runtime::{Analytics, NativeAnalytics, XlaAnalytics};
use cloudcoaster::sim::Rng;

fn xla() -> Option<XlaAnalytics> {
    match XlaAnalytics::load(&artifacts_dir()) {
        Ok(x) => Some(x),
        Err(err) => {
            eprintln!("skipping XLA roundtrip (artifacts not built?): {err:#}");
            None
        }
    }
}

#[test]
fn cluster_state_matches_native() {
    let Some(mut xla) = xla() else { return };
    let mut native = NativeAnalytics;
    let mut rng = Rng::new(1);
    for case in 0..5 {
        let n = [64usize, 512, 1000, 4000, 4096][case];
        let rw: Vec<f32> = (0..n).map(|_| (rng.f64() * 500.0) as f32).collect();
        let lc: Vec<f32> = (0..n).map(|_| rng.below(3) as f32).collect();
        let ql: Vec<f32> = (0..n).map(|_| rng.below(20) as f32).collect();
        let act: Vec<f32> = (0..n).map(|_| rng.below(2) as f32).collect();
        let a = xla.cluster_state(&rw, &lc, &ql, &act).unwrap();
        let b = native.cluster_state(&rw, &lc, &ql, &act).unwrap();
        assert_eq!(a.scores.len(), b.scores.len());
        for (x, y) in a.scores.iter().zip(&b.scores) {
            assert!((x - y).abs() <= 1e-3 * y.abs().max(1.0), "{x} vs {y}");
        }
        for (x, y) in a.stats.iter().zip(&b.stats) {
            assert!((x - y).abs() <= 1e-2 * y.abs().max(1.0), "stats {x} vs {y}");
        }
        assert!((a.l_r - b.l_r).abs() < 1e-5, "l_r {} vs {}", a.l_r, b.l_r);
    }
}

#[test]
fn cluster_state_lr_is_papers_formula() {
    let Some(mut xla) = xla() else { return };
    // 3800 of 4000 servers long-occupied -> l_r = 0.95 exactly (the
    // paper's threshold scenario).
    let n = 4000;
    let rw = vec![1.0f32; n];
    let mut lc = vec![1.0f32; n];
    for slot in lc.iter_mut().skip(3800) {
        *slot = 0.0;
    }
    let ql = vec![0.0f32; n];
    let act = vec![1.0f32; n];
    let out = xla.cluster_state(&rw, &lc, &ql, &act).unwrap();
    assert!((out.l_r - 0.95).abs() < 1e-6, "l_r = {}", out.l_r);
}

#[test]
fn concurrency_matches_native_with_chunking() {
    let Some(mut xla) = xla() else { return };
    let mut native = NativeAnalytics;
    let mut rng = Rng::new(2);
    // 40k tasks forces multi-chunk streaming (TASK_CHUNK = 16384).
    let n = 40_000;
    let starts: Vec<f32> = (0..n).map(|_| (rng.f64() * 10_000.0) as f32).collect();
    let ends: Vec<f32> =
        starts.iter().map(|&s| s + (rng.exponential(300.0) as f32)).collect();
    let times: Vec<f32> = (0..512).map(|i| i as f32 * 20.0).collect();
    let a = xla.concurrency(&starts, &ends, &times).unwrap();
    let b = native.concurrency(&starts, &ends, &times).unwrap();
    assert_eq!(a.len(), b.len());
    for (x, y) in a.iter().zip(&b) {
        assert!((x - y).abs() < 0.5, "{x} vs {y}"); // exact counts in f32
    }
}

#[test]
fn delay_cdf_matches_native_with_chunking() {
    let Some(mut xla) = xla() else { return };
    let mut native = NativeAnalytics;
    let mut rng = Rng::new(3);
    let n = 50_000; // multi-chunk (DELAY_CHUNK = 16384)
    let delays: Vec<f32> = (0..n).map(|_| rng.exponential(200.0) as f32).collect();
    let max = delays.iter().copied().fold(0.0f32, f32::max);
    let edges: Vec<f32> = (0..512).map(|i| max * i as f32 / 511.0).collect();
    let (ca, cdfa) = xla.delay_cdf(&delays, &edges).unwrap();
    let (cb, cdfb) = native.delay_cdf(&delays, &edges).unwrap();
    for (x, y) in ca.iter().zip(&cb) {
        assert!((x - y).abs() < 0.5, "counts {x} vs {y}");
    }
    for (x, y) in cdfa.iter().zip(&cdfb) {
        assert!((x - y).abs() < 1e-4, "cdf {x} vs {y}");
    }
    assert!((cdfa.last().unwrap() - 1.0).abs() < 1e-4);
}

#[test]
fn lr_forecast_matches_native() {
    let Some(mut xla) = xla() else { return };
    let mut native = NativeAnalytics;
    let mut rng = Rng::new(4);
    let w = cloudcoaster::runtime::artifacts::FORECAST_WINDOW;
    for h in [0.0f32, 2.0, 16.0] {
        let hist: Vec<f32> = (0..w).map(|_| rng.f64() as f32).collect();
        let (fa, la, sa) = xla.lr_forecast(&hist, h).unwrap();
        let (fb, lb, sb) = native.lr_forecast(&hist, h).unwrap();
        assert!((fa - fb).abs() < 1e-4, "forecast {fa} vs {fb}");
        assert!((la - lb).abs() < 1e-4, "level {la} vs {lb}");
        assert!((sa - sb).abs() < 1e-4, "slope {sa} vs {sb}");
        assert!((0.0..=1.0).contains(&fa));
    }
}

#[test]
fn lr_forecast_extrapolates_ramp() {
    let Some(mut xla) = xla() else { return };
    let w = cloudcoaster::runtime::artifacts::FORECAST_WINDOW;
    // Linear climb toward crowding: forecast ahead must exceed the last
    // sample — that's the pre-provisioning signal.
    let hist: Vec<f32> = (0..w).map(|k| 0.5 + 0.3 * k as f32 / w as f32).collect();
    let (forecast, _, slope) = xla.lr_forecast(&hist, 8.0).unwrap();
    assert!(slope > 0.0);
    assert!(forecast > *hist.last().unwrap(), "{forecast} <= {}", hist.last().unwrap());
}

#[test]
fn xla_runs_on_cpu_pjrt() {
    let Some(xla) = xla() else { return };
    assert!(xla.platform().to_lowercase().contains("cpu") || !xla.platform().is_empty());
}
