//! Federation goldens: the N = 1 pass-through federation reproduces the
//! plain single-`World` report bit-identically; N = 2 federated runs are
//! deterministic per seed and invariant under sweep thread count; a
//! pooled shared budget is never exceeded across clusters.

use cloudcoaster::coordinator::config::{ExperimentConfig, SchedulerKind, WorkloadSource};
use cloudcoaster::coordinator::report::{
    build_workload, run_experiment_on, run_federated_experiment_with, Report,
};
use cloudcoaster::coordinator::runner::run_federation;
use cloudcoaster::coordinator::scenario::{
    named, named_federation, BudgetSharing, FederationSpec, RouterKind,
};
use cloudcoaster::coordinator::sweep::{
    budget_sharing_points, router_points, run_sweep_parallel,
};
use cloudcoaster::runtime::NativeAnalytics;
use cloudcoaster::trace::synth::YahooLikeParams;

fn tiny_cfg(kind: SchedulerKind) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::paper_defaults();
    cfg.scheduler = kind;
    cfg.cluster_size = 120;
    cfg.short_partition = 8;
    cfg.threshold = 0.5;
    cfg.seed = 7;
    let mut p = YahooLikeParams::default();
    p.horizon = 2500.0;
    cfg.workload = WorkloadSource::YahooLike(p);
    cfg
}

fn assert_reports_bit_identical(a: &Report, b: &Report) {
    assert_eq!(a.name, b.name);
    assert_eq!(a.scheduler, b.scheduler);
    assert_eq!(a.events, b.events);
    assert_eq!(a.end_time.to_bits(), b.end_time.to_bits());
    assert_eq!(a.short_delay.n, b.short_delay.n);
    assert_eq!(a.short_delay.mean.to_bits(), b.short_delay.mean.to_bits());
    assert_eq!(a.short_delay.max.to_bits(), b.short_delay.max.to_bits());
    assert_eq!(a.short_delay.p50.to_bits(), b.short_delay.p50.to_bits());
    assert_eq!(a.short_delay.p99.to_bits(), b.short_delay.p99.to_bits());
    assert_eq!(a.long_delay.n, b.long_delay.n);
    assert_eq!(a.long_delay.mean.to_bits(), b.long_delay.mean.to_bits());
    assert_eq!(a.cdf.edges, b.cdf.edges);
    assert_eq!(a.cdf.values, b.cdf.values);
    assert_eq!(a.avg_transients.to_bits(), b.avg_transients.to_bits());
    assert_eq!(a.max_transients.to_bits(), b.max_transients.to_bits());
    assert_eq!(a.mean_lifetime_h.to_bits(), b.mean_lifetime_h.to_bits());
    assert_eq!(a.transients_requested, b.transients_requested);
    assert_eq!(a.transients_revoked, b.transients_revoked);
    assert_eq!(a.tasks_rescheduled, b.tasks_rescheduled);
    assert_eq!(a.peak_resident_jobs, b.peak_resident_jobs);
    assert_eq!(a.peak_resident_tasks, b.peak_resident_tasks);
    assert_eq!(a.peak_resident_servers, b.peak_resident_servers);
    assert_eq!(a.delay_struct_bytes, b.delay_struct_bytes);
}

/// The acceptance golden: an N = 1 federation with the pass-through
/// router is the plain single-world run, bit for bit, through the whole
/// report surface (wall-clock fields excepted).
#[test]
fn n1_passthrough_federation_reproduces_plain_world_report() {
    for kind in [SchedulerKind::Eagle, SchedulerKind::CloudCoaster] {
        let plain_cfg = tiny_cfg(kind);
        let workload = build_workload(&plain_cfg).unwrap();
        let mut analytics = NativeAnalytics;
        let plain = run_experiment_on(&plain_cfg, &workload, &mut analytics).unwrap();

        let mut fed_cfg = tiny_cfg(kind);
        fed_cfg.federation = Some(FederationSpec {
            clusters: 1,
            router: RouterKind::PassThrough,
            budget_sharing: BudgetSharing::None,
            stagger: 0.0,
            pdes_threads: 0,
        });
        let fed = run_federated_experiment_with(&fed_cfg, &mut analytics).unwrap();
        assert_eq!(fed.per_cluster.len(), 1);
        assert_reports_bit_identical(&plain, &fed.per_cluster[0]);
        // The aggregate of one cluster carries the same simulation
        // numbers (only its name and label fields differ).
        assert_eq!(fed.aggregate.events, plain.events);
        assert_eq!(fed.aggregate.end_time.to_bits(), plain.end_time.to_bits());
        assert_eq!(fed.aggregate.short_delay.n, plain.short_delay.n);
        assert_eq!(
            fed.aggregate.short_delay.mean.to_bits(),
            plain.short_delay.mean.to_bits()
        );
        assert_eq!(fed.aggregate.cdf.values, plain.cdf.values);
        assert_eq!(fed.aggregate.transients_requested, plain.transients_requested);
    }
}

/// N = 2 federated runs: deterministic per seed (every simulation field
/// repeats bit-exactly) across repeated runs, for both feed topologies.
#[test]
fn n2_federation_deterministic_per_seed() {
    for router in [RouterKind::PassThrough, RouterKind::RoundRobin, RouterKind::LeastQueued]
    {
        let mut cfg = tiny_cfg(SchedulerKind::CloudCoaster);
        cfg.scenario = Some(named("burst-storm", &cfg).unwrap());
        cfg.federation = Some(FederationSpec {
            clusters: 2,
            router,
            budget_sharing: BudgetSharing::Pooled,
            stagger: 400.0,
            pdes_threads: 0,
        });
        let mut analytics = NativeAnalytics;
        let a = run_federated_experiment_with(&cfg, &mut analytics).unwrap();
        let b = run_federated_experiment_with(&cfg, &mut analytics).unwrap();
        assert_eq!(a.per_cluster.len(), 2);
        assert_eq!(a.peak_total_fleet, b.peak_total_fleet, "router {router:?}");
        assert_eq!(a.aggregate.events, b.aggregate.events, "router {router:?}");
        assert_eq!(
            a.aggregate.end_time.to_bits(),
            b.aggregate.end_time.to_bits(),
            "router {router:?}"
        );
        assert_eq!(a.aggregate.short_delay.n, b.aggregate.short_delay.n);
        assert_eq!(
            a.aggregate.short_delay.mean.to_bits(),
            b.aggregate.short_delay.mean.to_bits()
        );
        assert_eq!(a.aggregate.cdf.values, b.aggregate.cdf.values);
        for (x, y) in a.per_cluster.iter().zip(&b.per_cluster) {
            assert_reports_bit_identical(x, y);
        }
        // The two members differ from each other (different seeds and
        // staggered storms) — the federation is not two copies.
        assert_ne!(
            a.per_cluster[0].end_time.to_bits(),
            a.per_cluster[1].end_time.to_bits()
        );
        // Aggregate counters are the member sums.
        assert_eq!(
            a.aggregate.events,
            a.per_cluster[0].events + a.per_cluster[1].events
        );
        assert_eq!(
            a.aggregate.short_delay.n,
            a.per_cluster[0].short_delay.n + a.per_cluster[1].short_delay.n
        );
        assert_eq!(
            a.aggregate.transients_requested,
            a.per_cluster[0].transients_requested + a.per_cluster[1].transients_requested
        );
    }
}

/// Federated grid points are simulation-bit-identical at any sweep
/// thread count, like every other grid axis.
#[test]
fn federated_sweep_invariant_under_thread_count() {
    let mut base = tiny_cfg(SchedulerKind::CloudCoaster);
    base.scenario = Some(named("burst-storm", &base).unwrap());
    base.federation = Some(FederationSpec {
        clusters: 2,
        router: RouterKind::PassThrough,
        budget_sharing: BudgetSharing::Pooled,
        stagger: 400.0,
        pdes_threads: 0,
    });
    let mut points = router_points(
        &base,
        &[RouterKind::PassThrough, RouterKind::RoundRobin],
    );
    points.extend(budget_sharing_points(&base));
    let serial = run_sweep_parallel(&base, &points, 1).unwrap();
    let parallel = run_sweep_parallel(&base, &points, 4).unwrap();
    assert_eq!(serial.len(), parallel.len());
    for (a, b) in serial.iter().zip(&parallel) {
        assert_eq!(a.name, b.name);
        assert_eq!(a.events, b.events);
        assert_eq!(a.end_time.to_bits(), b.end_time.to_bits());
        assert_eq!(a.short_delay.n, b.short_delay.n);
        assert_eq!(a.short_delay.mean.to_bits(), b.short_delay.mean.to_bits());
        assert_eq!(a.cdf.values, b.cdf.values);
        assert_eq!(a.transients_requested, b.transients_requested);
        assert_eq!(a.peak_resident_tasks, b.peak_resident_tasks);
    }
}

/// The cross-cluster budget invariant: under a pooled budget, the sum of
/// active + provisioning transients across clusters never exceeds the
/// pooled cap K — even with staggered storms pushing both clusters to
/// grow, and with aggressive revocation churning the fleet.
#[test]
fn pooled_shared_budget_cap_never_exceeded() {
    let mut cfg = tiny_cfg(SchedulerKind::CloudCoaster);
    cfg.threshold = 0.3; // aggressive growth: the cap must do the limiting
    cfg.mttf = Some(900.0); // churn: request/revoke all run long
    cfg.scenario = Some(named("burst-storm", &cfg).unwrap());
    cfg.federation = Some(FederationSpec {
        clusters: 2,
        router: RouterKind::PassThrough,
        budget_sharing: BudgetSharing::Pooled,
        stagger: 500.0,
        pdes_threads: 0,
    });
    let outcome = run_federation(&cfg).unwrap();
    let cap = outcome.shared_cap.expect("pooled sharing has a cap");
    assert_eq!(cap, 12); // r=3 · N_s=8 · p=0.5
    let requested: u64 = outcome.runs.iter().map(|r| r.rec.transients_requested).sum();
    assert!(requested > 0, "storms never triggered the managers");
    assert!(
        outcome.peak_total_fleet <= cap,
        "pooled budget overshot: peak {} > cap {}",
        outcome.peak_total_fleet,
        cap
    );
    // The pool actually coupled the clusters: the summed peak is also
    // what an uncoupled federation could have exceeded — verify the
    // uncoupled twin for contrast (it may legally go up to 2K).
    let mut uncoupled = cfg.clone();
    if let Some(f) = &mut uncoupled.federation {
        f.budget_sharing = BudgetSharing::None;
    }
    let free = run_federation(&uncoupled).unwrap();
    assert!(free.shared_cap.is_none());
    assert!(
        free.peak_total_fleet <= 2 * cap,
        "uncoupled members exceeded their own caps"
    );
}

/// Split sharing slices the pool: each member is capped at K/N, so the
/// summed fleet stays within K without any cross-cluster transfer.
#[test]
fn split_shared_budget_respects_slices() {
    let mut cfg = tiny_cfg(SchedulerKind::CloudCoaster);
    cfg.threshold = 0.3;
    cfg.scenario = Some(named("burst-storm", &cfg).unwrap());
    cfg.federation = Some(FederationSpec {
        clusters: 2,
        router: RouterKind::PassThrough,
        budget_sharing: BudgetSharing::Split,
        stagger: 0.0,
        pdes_threads: 0,
    });
    let outcome = run_federation(&cfg).unwrap();
    let cap = outcome.shared_cap.unwrap();
    assert!(
        outcome.peak_total_fleet <= cap,
        "split slices overshot the total: peak {} > {}",
        outcome.peak_total_fleet,
        cap
    );
}

/// The registry scenario end-to-end: `federated-burst` resolved against
/// a config runs two staggered-storm clusters under one pooled budget
/// and produces per-cluster + aggregate reports.
#[test]
fn federated_burst_registry_end_to_end() {
    let mut cfg = tiny_cfg(SchedulerKind::CloudCoaster);
    cfg.scenario = Some(named("federated-burst", &cfg).unwrap());
    cfg.federation = named_federation("federated-burst", &cfg).unwrap();
    assert!(cfg.federation.is_some());
    let mut analytics = NativeAnalytics;
    let fed = run_federated_experiment_with(&cfg, &mut analytics).unwrap();
    assert_eq!(fed.per_cluster.len(), 2);
    assert!(fed.shared_cap.is_some(), "registry scenario pools the budget");
    assert!(fed.peak_total_fleet <= fed.shared_cap.unwrap());
    assert!(fed.aggregate.short_delay.n > 0);
    assert!(
        fed.aggregate.cdf.values.last().copied().unwrap_or(0.0) > 0.999,
        "aggregate CDF must close at 1.0"
    );
    // Members see the storm at different times (staggered windows), so
    // their event streams differ.
    assert_ne!(
        fed.per_cluster[0].end_time.to_bits(),
        fed.per_cluster[1].end_time.to_bits()
    );
}

/// Runs `cfg` under the serial reference merge and under
/// conservative-window PDES at each thread count, asserting the whole
/// federated report surface is bit-identical every time.
fn assert_pdes_bit_identical(cfg: &ExperimentConfig, threads: &[usize]) {
    let mut analytics = NativeAnalytics;
    let mut serial_cfg = cfg.clone();
    if let Some(f) = &mut serial_cfg.federation {
        f.pdes_threads = 0;
    }
    let serial = run_federated_experiment_with(&serial_cfg, &mut analytics).unwrap();
    for &n in threads {
        let mut pdes_cfg = cfg.clone();
        if let Some(f) = &mut pdes_cfg.federation {
            f.pdes_threads = n;
        }
        let pdes = run_federated_experiment_with(&pdes_cfg, &mut analytics).unwrap();
        assert_eq!(
            serial.per_cluster.len(),
            pdes.per_cluster.len(),
            "pdes_threads {n}"
        );
        for (a, b) in serial.per_cluster.iter().zip(&pdes.per_cluster) {
            assert_reports_bit_identical(a, b);
        }
        assert_reports_bit_identical(&serial.aggregate, &pdes.aggregate);
        assert_eq!(
            serial.peak_total_fleet, pdes.peak_total_fleet,
            "pdes_threads {n}"
        );
        assert_eq!(serial.shared_cap, pdes.shared_cap, "pdes_threads {n}");
    }
}

/// The PDES acceptance pin: every router, under staggered burst storms
/// with an uncoupled budget, produces bit-identical per-cluster and
/// aggregate reports at 1, 2, and 8 worker threads vs the serial merge.
#[test]
fn pdes_routers_bit_identical_at_every_thread_count() {
    for router in [
        RouterKind::PassThrough,
        RouterKind::LeastQueued,
        RouterKind::ClassSplit,
    ] {
        let mut cfg = tiny_cfg(SchedulerKind::CloudCoaster);
        cfg.scenario = Some(named("burst-storm", &cfg).unwrap());
        cfg.federation = Some(FederationSpec {
            clusters: 2,
            router,
            budget_sharing: BudgetSharing::None,
            stagger: 400.0,
            pdes_threads: 0,
        });
        assert_pdes_bit_identical(&cfg, &[1, 2, 8]);
    }
}

/// Budget-sharing coverage: pooled contention with aggressive revocation
/// churn (the hardest coupling — members fight over one cap while
/// transients fail and release mid-window) and split slices both stay
/// bit-identical under PDES at 1, 2, and 8 threads.
#[test]
fn pdes_budget_sharing_bit_identical_at_every_thread_count() {
    for sharing in [BudgetSharing::Pooled, BudgetSharing::Split] {
        let mut cfg = tiny_cfg(SchedulerKind::CloudCoaster);
        cfg.threshold = 0.3; // aggressive growth: the caps do the limiting
        cfg.mttf = Some(900.0); // churn: request/revoke/release all run long
        cfg.scenario = Some(named("burst-storm", &cfg).unwrap());
        cfg.federation = Some(FederationSpec {
            clusters: 2,
            router: RouterKind::LeastQueued,
            budget_sharing: sharing,
            stagger: 500.0,
            pdes_threads: 0,
        });
        assert_pdes_bit_identical(&cfg, &[1, 2, 8]);
    }
}

/// The `[federation]` TOML block drives the same path end-to-end.
#[test]
fn federation_toml_block_end_to_end() {
    let cfg = ExperimentConfig::from_toml(
        r#"
        seed = 7
        [cluster]
        servers = 120
        short_partition = 8
        [transient]
        threshold = 0.5
        [workload]
        horizon = 2500
        [scenario]
        name = "staggered-storm"
        storm_windows = [600, 1000]
        storm_intensity = 3.0
        [federation]
        clusters = 2
        router = "round-robin"
        budget_sharing = "pooled"
        stagger = 400
        "#,
    )
    .unwrap();
    let mut analytics = NativeAnalytics;
    let fed = run_federated_experiment_with(&cfg, &mut analytics).unwrap();
    assert_eq!(fed.per_cluster.len(), 2);
    assert!(fed.aggregate.events > 0);
    assert!(fed.peak_total_fleet <= fed.shared_cap.unwrap());
    // Round-robin splits the merged stream: both members run work.
    assert!(fed.per_cluster.iter().all(|r| r.short_delay.n + r.long_delay.n > 0));
}
