//! Golden equivalence for the streaming workload path: a `World` fed by
//! a lazy [`ArrivalSource`] must reproduce the eager `&Workload` replay
//! **bit-exactly** — same event count, same end time, same per-task
//! delay sequences — for both the Eagle baseline and CloudCoaster
//! (manager + stealing paths); plus determinism pins for the source
//! combinators, the CSV round-trip, the `[scenario]` TOML pipeline, and
//! the streaming-memory guarantee (peak resident jobs independent of
//! trace length).
//!
//! (`tests/golden_determinism.rs` separately pins the `World` event loop
//! against the pre-refactor monolithic runner; together the two suites
//! give eager == World == streaming.)

use cloudcoaster::coordinator::config::{ExperimentConfig, SchedulerKind, WorkloadSource};
use cloudcoaster::coordinator::report::run_experiment;
use cloudcoaster::coordinator::runner::{simulate, simulate_source, RunResult, SimConfig};
use cloudcoaster::coordinator::scenario;
use cloudcoaster::sched::Hybrid;
use cloudcoaster::sim::Rng;
use cloudcoaster::trace::synth::{yahoo_like, YahooLikeParams, YahooSource};
use cloudcoaster::trace::{
    collect_jobs, write_csv, BurstStorm, CsvStream, Mmpp, Splice, VecSource,
};
use cloudcoaster::transient::{Budget, ManagerConfig};

fn golden_params() -> YahooLikeParams {
    let mut p = YahooLikeParams::default();
    p.horizon = 4000.0;
    p
}

fn assert_same_run(eager: &RunResult, streamed: &RunResult) {
    assert_eq!(eager.events, streamed.events, "event count diverged");
    assert_eq!(eager.end_time, streamed.end_time, "end time diverged");
    assert_eq!(eager.rec.tasks_finished, streamed.rec.tasks_finished);
    assert_eq!(eager.rec.transients_requested, streamed.rec.transients_requested);
    // Whole-distribution equality: on the default histogram backend the
    // bucket counts, push-order sum and min/max compare bit-exactly; on
    // the exact backend this is the full sample sequence.
    assert_eq!(
        eager.rec.short_delays, streamed.rec.short_delays,
        "short-delay distribution diverged"
    );
    assert_eq!(
        eager.rec.long_delays, streamed.rec.long_delays,
        "long-delay distribution diverged"
    );
    assert_eq!(eager.manager_stats, streamed.manager_stats);
    assert_eq!(eager.peak_resident_servers, streamed.peak_resident_servers);
}

#[test]
fn streaming_matches_eager_eagle() {
    for seed in [3u64, 9, 17] {
        let p = golden_params();
        let w = yahoo_like(&p, &mut Rng::new(seed));
        let cfg = SimConfig { n_general: 128, n_short_reserved: 8, seed, ..Default::default() };
        let mut eager_sched = Hybrid::eagle(2.0);
        let eager = simulate(&w, &mut eager_sched, &cfg);
        let mut stream_sched = Hybrid::eagle(2.0);
        let source = Box::new(YahooSource::new(&p, &mut Rng::new(seed)));
        let streamed = simulate_source(source, &mut stream_sched, &cfg, None);
        assert_same_run(&eager, &streamed);
    }
}

#[test]
fn streaming_matches_eager_cloudcoaster() {
    for seed in [3u64, 5] {
        let p = golden_params();
        let w = yahoo_like(&p, &mut Rng::new(seed));
        let mut cfg =
            SimConfig { n_general: 128, n_short_reserved: 4, seed, ..Default::default() };
        cfg.manager = Some(ManagerConfig {
            threshold: 0.6,
            ..ManagerConfig::paper(Budget::new(8, 0.5, 3.0))
        });
        let mut eager_sched = Hybrid::cloudcoaster(2.0);
        let eager = simulate(&w, &mut eager_sched, &cfg);
        let mut stream_sched = Hybrid::cloudcoaster(2.0);
        let source = Box::new(YahooSource::new(&p, &mut Rng::new(seed)));
        let streamed = simulate_source(source, &mut stream_sched, &cfg, None);
        assert_same_run(&eager, &streamed);
    }
}

#[test]
fn csv_replay_stream_matches_eager_run() {
    let p = golden_params();
    let w = yahoo_like(&p, &mut Rng::new(11));
    let mut path = std::env::temp_dir();
    path.push(format!("cloudcoaster_golden_replay_{}.csv", std::process::id()));
    write_csv(&w, &path).unwrap();

    let cfg = SimConfig { n_general: 128, n_short_reserved: 8, seed: 11, ..Default::default() };
    let mut eager_sched = Hybrid::eagle(2.0);
    let eager = simulate(&w, &mut eager_sched, &cfg);
    let mut stream_sched = Hybrid::eagle(2.0);
    let source = Box::new(CsvStream::open(&path, w.cutoff).unwrap());
    let streamed = simulate_source(source, &mut stream_sched, &cfg, None);
    assert_same_run(&eager, &streamed);
    std::fs::remove_file(path).ok();
}

#[test]
fn burst_storm_and_splice_deterministic_under_fixed_seeds() {
    let run = |seed: u64| -> Vec<(u64, u64)> {
        // storm(yahoo) spliced into a hand-built steady tail.
        let p = golden_params();
        let storm = BurstStorm::new(
            Box::new(YahooSource::new(&p, &mut Rng::new(seed))),
            vec![(1000.0, 2000.0)],
            2.5,
        );
        let tail: Vec<cloudcoaster::trace::Job> = (0..50)
            .map(|i| cloudcoaster::trace::Job {
                id: cloudcoaster::util::JobId(0),
                arrival: i as f64 * 10.0,
                task_durations: vec![5.0, 5.0],
                is_long: false,
            })
            .collect();
        let mut spliced = Splice::new(
            Box::new(storm),
            Box::new(VecSource::new(tail, 90.0)),
            3000.0,
        );
        collect_jobs(&mut spliced, &mut Rng::new(seed))
            .iter()
            .map(|j| (j.arrival.to_bits(), j.task_durations.len() as u64))
            .collect()
    };
    let a = run(7);
    let b = run(7);
    assert!(!a.is_empty());
    assert_eq!(a, b, "combinator pipeline not deterministic under a fixed seed");
    let c = run(8);
    assert_ne!(a, c, "seed does not influence the pipeline");
    // Ordering survives the whole stack (arrivals are nonnegative, so
    // bit order == numeric order).
    assert!(a.windows(2).all(|w| w[0].0 <= w[1].0));
}

#[test]
fn peak_resident_jobs_independent_of_trace_length() {
    // A tame, non-backlogged workload: Poisson shorts only, sized so a
    // 64-server cluster keeps up. Doubling the horizon doubles total
    // jobs but must NOT grow the resident high-water mark.
    let run = |horizon: f64| -> (usize, u64) {
        let mut p = YahooLikeParams::default();
        p.horizon = horizon;
        p.short_arrivals = Mmpp::poisson(0.5);
        p.long_arrivals = Mmpp::poisson(0.0); // no longs
        p.short_tasks_mean = 4.0;
        p.short_tasks_max = 8;
        p.short_dur_mu = 2.0; // ~ 8 s tasks
        p.short_dur_sigma = 0.4;
        let cfg = SimConfig {
            n_general: 48,
            n_short_reserved: 16,
            seed: 1,
            ..Default::default()
        };
        let mut sched = Hybrid::eagle(2.0);
        let source = Box::new(YahooSource::new(&p, &mut Rng::new(1)));
        let res = simulate_source(source, &mut sched, &cfg, None);
        (res.peak_resident_jobs, res.rec.tasks_finished)
    };
    let (peak_short, tasks_short) = run(4000.0);
    let (peak_long, tasks_long) = run(16_000.0);
    assert!(tasks_long > 3 * tasks_short, "long run did not scale the trace");
    assert!(peak_short > 0);
    // The resident bound is set by load, not length: allow slack for
    // the longer run sampling deeper into the arrival distribution.
    assert!(
        peak_long <= peak_short * 2 + 16,
        "peak resident jobs grew with trace length: {peak_short} -> {peak_long}"
    );
}

/// Burst-storm scenario used by the arena-memory pins: an early 8x storm
/// sets the task high-water mark, a mild tail follows for the rest of
/// `horizon`. Extending the horizon scales total tasks but not the peak.
/// `tweak` customizes the SimConfig (arena/backend reference modes).
fn storm_run_with(horizon: f64, tweak: impl FnOnce(&mut SimConfig)) -> RunResult {
    let mut p = YahooLikeParams::default();
    p.horizon = horizon;
    p.short_arrivals = Mmpp::poisson(0.4);
    p.long_arrivals = Mmpp::poisson(0.0); // shorts only: cluster keeps up
    p.short_tasks_mean = 4.0;
    p.short_tasks_max = 8;
    p.short_dur_mu = 2.0;
    p.short_dur_sigma = 0.4;
    let source = Box::new(BurstStorm::new(
        Box::new(YahooSource::new(&p, &mut Rng::new(7))),
        vec![(0.0, 400.0)],
        8.0,
    ));
    let mut cfg = SimConfig {
        n_general: 48,
        n_short_reserved: 16,
        seed: 7,
        ..Default::default()
    };
    tweak(&mut cfg);
    let mut sched = Hybrid::eagle(2.0);
    simulate_source(source, &mut sched, &cfg, None)
}

fn storm_run(horizon: f64, recycle: bool) -> RunResult {
    storm_run_with(horizon, |cfg| cfg.recycle_task_slots = recycle)
}

#[test]
fn arena_recycling_report_bits_identical_to_append_only() {
    // The acceptance golden: with recycling on, every simulation field
    // of the report is bit-identical to the pre-arena (append-only)
    // behaviour — including peak_resident_tasks, whose liveness
    // accounting is mode-independent.
    let with = storm_run(4000.0, true);
    let without = storm_run(4000.0, false);
    assert_same_run(&without, &with);
    assert_eq!(with.peak_resident_jobs, without.peak_resident_jobs);
    assert_eq!(with.peak_resident_tasks, without.peak_resident_tasks);
    assert!(with.peak_resident_tasks > 0);
    // Both job delay sequences identical was checked; also pin the
    // end-time bits explicitly (f64 equality above is already bitwise
    // for non-NaN, this documents intent).
    assert_eq!(with.end_time.to_bits(), without.end_time.to_bits());
}

#[test]
fn all_reference_modes_report_bits_identical_to_defaults() {
    // The PR-4 acceptance golden: defaults (task + server recycling,
    // histogram delay sketches) vs the full reference configuration
    // (append-only arenas, exact delay Vecs). Every simulation field
    // must agree bit-exactly except the explicitly-approximate
    // quantile surfaces, which only exist report-side.
    let defaults = storm_run_with(4000.0, |_| {});
    let reference = storm_run_with(4000.0, |cfg| {
        cfg.recycle_task_slots = false;
        cfg.recycle_server_slots = false;
        cfg.exact_delay_samples = true;
    });
    assert_eq!(defaults.events, reference.events);
    assert_eq!(defaults.end_time.to_bits(), reference.end_time.to_bits());
    assert_eq!(defaults.rec.tasks_finished, reference.rec.tasks_finished);
    assert_eq!(defaults.rec.stale_copies_skipped, reference.rec.stale_copies_skipped);
    assert_eq!(defaults.manager_stats, reference.manager_stats);
    assert_eq!(defaults.peak_resident_jobs, reference.peak_resident_jobs);
    assert_eq!(defaults.peak_resident_tasks, reference.peak_resident_tasks);
    assert_eq!(defaults.peak_resident_servers, reference.peak_resident_servers);
    // Across delay backends: count/mean/max are exact and bit-equal...
    for (sk, ex) in [
        (&defaults.rec.short_delays, &reference.rec.short_delays),
        (&defaults.rec.long_delays, &reference.rec.long_delays),
    ] {
        assert_eq!(sk.len(), ex.len());
        assert_eq!(sk.mean().to_bits(), ex.mean().to_bits(), "mean not bit-identical");
        assert_eq!(sk.max().to_bits(), ex.max().to_bits(), "max not bit-identical");
        assert_eq!(sk.min().to_bits(), ex.min().to_bits(), "min not bit-identical");
    }
    // ...and quantiles stay within the histogram's documented bound
    // (≤1% relative, sub-ms absolute floor for near-zero delays).
    let mut sk = defaults.rec.short_delays.clone();
    let mut ex = reference.rec.short_delays.clone();
    for q in [0.5, 0.9, 0.99] {
        let (a, b) = (sk.percentile(q), ex.percentile(q));
        assert!(
            (a - b).abs() <= 0.011 * b.abs() + 1e-3,
            "q={q} diverged past the bucket bound: sketch {a} vs exact {b}"
        );
    }
}

#[test]
fn peak_resident_tasks_flat_under_10x_trace_scaling() {
    // The O(active)-memory acceptance criterion: a fixed-seed burst-storm
    // run at 10x the trace length reports the *same* peak_resident_tasks
    // as at 1x — the high-water mark is set by the (identical) storm
    // prefix, and the arena recycles everything after it.
    let short = storm_run(4000.0, true);
    let long = storm_run(40_000.0, true);
    assert!(
        long.rec.tasks_finished > 5 * short.rec.tasks_finished,
        "long run did not scale the trace ({} vs {})",
        long.rec.tasks_finished,
        short.rec.tasks_finished
    );
    assert!(short.peak_resident_tasks > 0);
    assert_eq!(
        long.peak_resident_tasks, short.peak_resident_tasks,
        "peak resident tasks grew with trace length"
    );
    // Jobs stay flat too (the PR 2 guarantee, still holding), and the
    // fixed-size delay sketches don't grow at all.
    assert_eq!(long.peak_resident_jobs, short.peak_resident_jobs);
    assert_eq!(
        long.rec.delay_struct_bytes(),
        short.rec.delay_struct_bytes(),
        "delay-structure memory grew with trace length"
    );
}

/// Revocation-churn scenario for the server-arena pins: CloudCoaster
/// with an aggressive MTTF, so transients are requested, revoked and
/// re-requested continuously for the whole horizon. Transients *ever
/// requested* scales with the horizon; peak *concurrent* transients is
/// capped by the budget, so the server arena must stay flat.
fn churn_run(horizon: f64, recycle_servers: bool) -> RunResult {
    churn_run_with(horizon, |cfg| cfg.recycle_server_slots = recycle_servers)
}

fn churn_run_with(horizon: f64, tweak: impl FnOnce(&mut SimConfig)) -> RunResult {
    let mut p = golden_params();
    p.horizon = horizon;
    let mut cfg = SimConfig {
        n_general: 96,
        n_short_reserved: 4,
        seed: 5,
        ..Default::default()
    };
    tweak(&mut cfg);
    let mut mgr = ManagerConfig {
        threshold: 0.5,
        ..ManagerConfig::paper(Budget::new(8, 0.5, 3.0)) // K = 12
    };
    mgr.market.mttf = Some(600.0); // heavy revocations
    cfg.manager = Some(mgr);
    let mut sched = Hybrid::cloudcoaster(2.0);
    let source = Box::new(YahooSource::new(&p, &mut Rng::new(5)));
    simulate_source(source, &mut sched, &cfg, None)
}

#[test]
fn server_recycling_report_bits_identical_to_append_only() {
    let with = churn_run(4000.0, true);
    let without = churn_run(4000.0, false);
    assert_same_run(&without, &with);
    assert_eq!(with.rec.transients_revoked, without.rec.transients_revoked);
    assert!(with.rec.transients_revoked > 0, "churn scenario produced no revocations");
}

#[test]
fn peak_resident_servers_bounded_under_10x_revocation_churn() {
    // The server-arena acceptance criterion: requested transients scale
    // with the horizon, but the arena high-water mark stays bounded by
    // static size + the budget cap K — slots recycle through the free
    // list instead of accumulating one per lease.
    let n_static = 96 + 4;
    let cap = 12; // K = r·N_s·p = 3 · 8 · 0.5
    let short = churn_run(4000.0, true);
    let long = churn_run(40_000.0, true);
    assert!(
        long.rec.transients_requested > 3 * short.rec.transients_requested.max(1),
        "long run did not scale transient churn ({} vs {})",
        long.rec.transients_requested,
        short.rec.transients_requested
    );
    assert!(
        long.rec.transients_requested > (n_static + cap) as u64,
        "not enough churn to exercise slot reuse"
    );
    for run in [&short, &long] {
        assert!(
            run.peak_resident_servers <= n_static + cap,
            "server arena exceeded static + budget cap: {}",
            run.peak_resident_servers
        );
    }
    // Flatness under 10x: the high-water mark is set by load and the
    // budget cap, not by how long the churn continues.
    assert!(
        long.peak_resident_servers <= short.peak_resident_servers.max(n_static + 1) + cap,
        "peak resident servers grew with trace length: {} -> {}",
        short.peak_resident_servers,
        long.peak_resident_servers
    );
}

#[test]
fn soa_hot_fields_off_report_bits_identical_to_defaults() {
    // The PR-8 tentpole golden: serving hot per-server fields from the
    // dense struct-of-arrays mirror (default) vs reading them back
    // through the reference `Server` structs must agree on every
    // simulation bit — the mirror is maintained unconditionally, the
    // toggle only switches the read path.
    let dense = storm_run_with(4000.0, |_| {});
    let structs = storm_run_with(4000.0, |cfg| cfg.soa_hot_fields = false);
    assert_same_run(&dense, &structs);
    assert_eq!(dense.peak_resident_jobs, structs.peak_resident_jobs);
    assert_eq!(dense.peak_resident_tasks, structs.peak_resident_tasks);

    // And under CloudCoaster revocation churn, where every transition
    // that must refresh the mirror (provision, ready, drain, revoke,
    // retire, steal) fires continuously.
    let dense = churn_run_with(4000.0, |_| {});
    let structs = churn_run_with(4000.0, |cfg| cfg.soa_hot_fields = false);
    assert_same_run(&dense, &structs);
    assert!(dense.rec.transients_revoked > 0, "churn scenario produced no revocations");
}

#[test]
fn profiling_does_not_perturb_simulation_bits() {
    // Profiling is excluded from the bit-identity surface: a profiled
    // run reports the exact same simulation bits as an unprofiled one.
    let plain = storm_run_with(4000.0, |_| {});
    let profiled = storm_run_with(4000.0, |cfg| cfg.profile = true);
    assert_same_run(&plain, &profiled);
    assert!(plain.profile.is_none(), "profile produced without profile=true");
    let prof = profiled.profile.as_ref().expect("profiled run lost its profile");
    // Every popped event is counted — stale finishes included — so the
    // profiler's total matches the engine's processed count exactly.
    assert_eq!(prof.events_total(), profiled.events);
    assert!(prof.to_json().contains("\"events_total\""));

    // Event counts and pool counters are pure functions of the run:
    // bit-identical run to run (wall times are not, and aren't pinned).
    let again = storm_run_with(4000.0, |cfg| cfg.profile = true);
    let prof2 = again.profile.as_ref().unwrap();
    let counts: Vec<(&str, u64)> = prof.by_kind.iter().map(|&(k, c, _)| (k, c)).collect();
    let counts2: Vec<(&str, u64)> = prof2.by_kind.iter().map(|&(k, c, _)| (k, c)).collect();
    assert_eq!(counts, counts2, "profiler event counts not deterministic");
    assert_eq!(prof.pools, prof2.pools, "pool counters not deterministic");
}

#[test]
fn churn_profile_shows_steady_state_pool_reuse() {
    // The zero-alloc acceptance evidence: under continuous revocation
    // churn the allocation pools serve the steady state — retired
    // transients donate server slots and queue buffers that later
    // leases reuse, so misses are confined to cold starts.
    let plain = churn_run_with(4000.0, |_| {});
    let profiled = churn_run_with(4000.0, |cfg| cfg.profile = true);
    assert_same_run(&plain, &profiled);
    let prof = profiled.profile.as_ref().unwrap();
    assert_eq!(prof.events_total(), profiled.events);
    assert!(profiled.rec.transients_revoked > 0, "no churn to measure");
    assert!(
        prof.pools.server_slot_hits > 0,
        "no server-slot reuse under churn: {:?}",
        prof.pools
    );
    assert!(
        prof.pools.queue_buf_hits > 0,
        "no queue-buffer reuse under churn: {:?}",
        prof.pools
    );
    assert!(
        prof.pools.task_slot_hits > prof.pools.task_slot_misses,
        "steady state should be dominated by task-slot reuse: {:?}",
        prof.pools
    );
}

#[test]
fn scenario_toml_burst_storm_replay_end_to_end() {
    // Acceptance scenario: CSV trace replay + injected burst storm +
    // manager-less baseline, all from one [scenario] TOML block.
    let mut p = golden_params();
    p.horizon = 3000.0;
    let w = yahoo_like(&p, &mut Rng::new(13));
    let mut path = std::env::temp_dir();
    path.push(format!("cloudcoaster_scenario_replay_{}.csv", std::process::id()));
    write_csv(&w, &path).unwrap();

    let toml = format!(
        r#"
        seed = 13
        [cluster]
        servers = 136
        short_partition = 8
        [workload]
        csv = "{}"
        [scenario]
        name = "storm-replay"
        storm_windows = [750, 1200]
        storm_intensity = 3
        manager = "none"
        "#,
        path.display()
    );
    let cfg = ExperimentConfig::from_toml(&toml).unwrap();
    assert_eq!(cfg.scheduler, SchedulerKind::CloudCoaster); // default kind
    assert!(matches!(cfg.workload, WorkloadSource::Csv(_)));
    let spec = cfg.scenario.as_ref().unwrap();
    assert!(spec.manager_off && spec.reshapes_workload());

    let rep = run_experiment(&cfg).unwrap();
    assert!(rep.short_delay.n > 0, "no tasks completed");
    assert_eq!(rep.transients_requested, 0, "manager-less run requested transients");
    assert!(rep.peak_resident_jobs > 0);
    assert!(rep.name.contains("storm-replay"));

    // The same spec run twice is bit-deterministic.
    let rep2 = run_experiment(&cfg).unwrap();
    assert_eq!(rep.events, rep2.events);
    assert_eq!(rep.end_time, rep2.end_time);
    std::fs::remove_file(path).ok();
}

#[test]
fn managerless_registry_scenario_drops_the_manager() {
    let mut cfg = ExperimentConfig::paper_defaults();
    cfg.cluster_size = 120;
    cfg.short_partition = 8;
    cfg.threshold = 0.5;
    let mut p = YahooLikeParams::default();
    p.horizon = 2000.0;
    cfg.workload = WorkloadSource::YahooLike(p);
    cfg.scenario = Some(scenario::named("managerless", &cfg).unwrap());

    let sim = cfg.to_sim_config();
    assert!(sim.manager.is_none(), "managerless scenario kept the manager");
    let rep = run_experiment(&cfg).unwrap();
    assert_eq!(rep.transients_requested, 0);
    assert_eq!(rep.avg_transients, 0.0);

    // Against the same geometry with the manager on, the manager-less
    // baseline completes the same workload (robustness, not speed).
    cfg.scenario = None;
    let with_mgr = run_experiment(&cfg).unwrap();
    assert_eq!(rep.short_delay.n, with_mgr.short_delay.n);
    assert!(with_mgr.transients_requested > 0);
}
