pub mod a;
pub mod b;
use a::one as thing;
use b::two as other;

pub(crate) fn go() -> u32 {
    thing() + other()
}
