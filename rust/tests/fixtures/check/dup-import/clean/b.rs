pub(crate) fn two() -> u32 {
    2
}
