pub(crate) fn one() -> u32 {
    1
}
