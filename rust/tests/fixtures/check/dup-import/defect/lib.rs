pub mod a;
pub mod b;
use a::one as thing;
use b::two as thing;

pub(crate) fn go() -> u32 {
    thing()
}
