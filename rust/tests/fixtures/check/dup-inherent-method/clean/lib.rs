pub(crate) struct Relay;

impl Relay {
    pub(crate) fn fire(&self) -> u32 {
        1
    }
}

impl Relay {
    pub(crate) fn douse(&self) -> u32 {
        2
    }
}
