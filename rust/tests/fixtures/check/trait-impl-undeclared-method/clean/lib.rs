pub mod a;

pub(crate) struct Greedy;

impl a::Policy for Greedy {
    fn pick(&self, n: usize) -> usize {
        n
    }
}

impl Greedy {
    pub(crate) fn extra(&self) -> usize {
        0
    }
}
