pub(crate) trait Policy {
    fn pick(&self, n: usize) -> usize;
}
