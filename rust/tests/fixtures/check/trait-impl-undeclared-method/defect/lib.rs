pub mod a;

pub(crate) struct Greedy;

impl a::Policy for Greedy {
    fn pick(&self, n: usize) -> usize {
        n
    }

    fn extra(&self) -> usize {
        0
    }
}
