pub(crate) enum Event {
    Arrive,
    Depart,
    Tick,
}

impl Event {
    pub(crate) const N_KINDS: usize = 3;
    pub(crate) const KINDS: [&'static str; 3] = ["arrive", "depart", "tick"];

    pub(crate) fn kind_index(&self) -> usize {
        match self {
            Event::Arrive => 0,
            Event::Depart => 1,
            Event::Tick => 2,
        }
    }
}

pub(crate) fn dispatch_event_core(ev: &Event) -> usize {
    match ev {
        Event::Arrive => 1,
        Event::Depart => 2,
        Event::Tick => 3,
    }
}
