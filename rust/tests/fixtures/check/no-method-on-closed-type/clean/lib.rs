pub(crate) struct Counter {
    count: u32,
}

impl Counter {
    pub(crate) fn clear(&mut self) {
        self.count = 0;
    }

    pub(crate) fn tick(&mut self) {
        self.clear();
    }
}
