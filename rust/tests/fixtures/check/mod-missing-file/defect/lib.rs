pub mod ghost;
