pub(crate) fn haunt() {}
