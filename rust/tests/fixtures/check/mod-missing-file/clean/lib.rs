pub mod ghost;
