pub(crate) struct Thing;
