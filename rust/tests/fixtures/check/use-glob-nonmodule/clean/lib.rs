pub mod a;
use a::*;
