pub mod a;
use a::Thing::*;
