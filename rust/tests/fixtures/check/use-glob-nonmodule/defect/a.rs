pub(crate) struct Thing;
