pub mod a;

pub(crate) struct Greedy;

impl a::Policy for Greedy {}
