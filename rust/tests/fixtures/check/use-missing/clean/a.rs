pub(crate) fn helper() {}
