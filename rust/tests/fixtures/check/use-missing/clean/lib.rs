pub mod a;
use a::helper;
