pub mod a;
use a::missing_fn;
