pub(crate) fn helper() {}
