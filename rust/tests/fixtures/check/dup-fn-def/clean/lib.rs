pub(crate) fn poll() -> u32 {
    1
}

pub(crate) fn drain() -> u32 {
    2
}
