pub(crate) fn run() {}
