pub mod a;

pub(crate) fn go() {
    a::run();
}
