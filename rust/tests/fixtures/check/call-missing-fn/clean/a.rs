pub(crate) fn run() {}
