pub(crate) struct Gauge;

impl Gauge {
    pub(crate) fn read(&self, idx: usize) -> usize {
        idx
    }
}
