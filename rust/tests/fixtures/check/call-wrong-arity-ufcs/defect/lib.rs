pub mod a;

pub(crate) fn go(g: &a::Gauge) -> usize {
    a::Gauge::read(g)
}
