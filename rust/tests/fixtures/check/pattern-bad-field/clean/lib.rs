pub mod a;

pub(crate) fn go(c: a::Cfg) -> u32 {
    let a::Cfg { rate, cap } = c;
    rate + cap
}
