pub mod a;

pub(crate) fn go(c: a::Cfg) -> u32 {
    let a::Cfg { rate, capp } = c;
    rate + capp
}
