pub mod a;

pub(crate) fn go() -> a::Msg {
    a::Msg::Stop(3)
}
