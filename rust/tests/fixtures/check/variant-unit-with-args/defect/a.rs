pub(crate) enum Msg {
    Ping(u32),
    Stop,
}
