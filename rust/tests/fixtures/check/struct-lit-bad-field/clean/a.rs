pub(crate) struct Cfg {
    pub(crate) rate: u32,
    pub(crate) cap: u32,
}
