pub mod a;

pub(crate) fn go() -> u32 {
    let c = a::Cfg { rate: 1, capp: 2 };
    c.rate
}
