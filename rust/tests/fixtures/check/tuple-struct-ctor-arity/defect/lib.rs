pub mod a;

pub(crate) fn go() -> a::Pair {
    a::Pair(1)
}
