pub(crate) struct Pair(u32, u32);
