pub mod a;

pub(crate) fn go() -> a::Job {
    a::Job::Spawn { id: 1 }
}
