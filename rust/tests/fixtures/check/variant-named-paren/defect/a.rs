pub(crate) enum Job {
    Spawn { id: u32 },
    Halt,
}
