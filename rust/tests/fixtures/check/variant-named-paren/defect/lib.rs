pub mod a;

pub(crate) fn go() -> a::Job {
    a::Job::Spawn(1)
}
