// lint: allow(check-dead-pub): staged API, wired up by the next PR
pub fn staged_api() -> u32 {
    7
}
