pub mod a;
