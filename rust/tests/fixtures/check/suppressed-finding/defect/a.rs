pub fn staged_api() -> u32 {
    7
}
