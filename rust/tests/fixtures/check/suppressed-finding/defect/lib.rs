pub mod a;
