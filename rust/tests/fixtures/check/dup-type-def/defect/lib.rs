pub(crate) struct Slot {
    a: u32,
}

pub(crate) struct Slot {
    b: u32,
}
