pub(crate) struct Slot {
    a: u32,
}

pub(crate) struct Bay {
    b: u32,
}
