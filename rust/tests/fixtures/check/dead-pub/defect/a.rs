pub fn orphan_api() -> u32 {
    7
}
