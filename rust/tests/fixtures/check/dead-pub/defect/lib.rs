pub mod a;
