pub mod a;

pub(crate) fn go() -> u32 {
    a::LIMIT
}
