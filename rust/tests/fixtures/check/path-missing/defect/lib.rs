pub mod a;

pub(crate) fn go() -> u32 {
    a::CONST_MISSING
}
