pub(crate) const LIMIT: u32 = 3;
