pub(crate) struct Counter {
    count: u32,
}

impl Counter {
    pub(crate) fn bump(&mut self, by: u32) {
        self.count += by;
    }

    pub(crate) fn tick(&mut self) {
        self.bump(1);
    }
}
