pub(crate) struct Counter {
    count: u32,
}

impl Counter {
    pub(crate) fn total(&self) -> u32 {
        self.countt
    }
}
