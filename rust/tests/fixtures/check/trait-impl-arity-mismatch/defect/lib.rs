pub mod a;

pub(crate) struct Greedy;

impl a::Policy for Greedy {
    fn pick(&self) -> usize {
        0
    }
}
