pub mod a;

pub(crate) fn go() -> u32 {
    a::scale(1, 2)
}
