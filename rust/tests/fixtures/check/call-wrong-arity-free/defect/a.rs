pub(crate) fn scale(x: u32, f: u32) -> u32 {
    x * f
}
