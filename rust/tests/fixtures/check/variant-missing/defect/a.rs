pub(crate) enum Mode {
    On,
    Off,
}
