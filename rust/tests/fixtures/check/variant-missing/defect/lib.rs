pub mod a;

pub(crate) fn go() -> a::Mode {
    a::Mode::Standby
}
