pub mod a;

pub(crate) fn go() -> a::Msg {
    a::Msg::Ping(1)
}
