//! Minimal offline stand-in for the `anyhow` crate.
//!
//! This build environment has no crate registry access, so the simulator
//! vendors the small slice of anyhow's API it actually uses: the opaque
//! [`Error`] type, the [`Result`] alias, the [`Context`] extension trait
//! (on `Result` and `Option`), and the `anyhow!` / `bail!` / `ensure!`
//! macros. Semantics match upstream for this surface:
//!
//! * `?` converts any `E: std::error::Error + Send + Sync + 'static`.
//! * `.context(..)` / `.with_context(..)` prepend a message layer.
//! * `Display` prints the outermost message; `{:#}` prints the whole
//!   chain separated by `": "`; `Debug` prints the chain with a
//!   "Caused by" block (what `fn main() -> Result<()>` shows on exit).

use std::error::Error as StdError;
use std::fmt;

/// Opaque, context-carrying error. Deliberately does **not** implement
/// `std::error::Error`, so the blanket `From<E: StdError>` conversion
/// below cannot overlap the reflexive `From<Error> for Error`.
pub struct Error {
    /// Message layers, outermost context first; the last entry is the
    /// root cause's rendering.
    chain: Vec<String>,
}

impl Error {
    /// Build an error from a printable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { chain: vec![message.to_string()] }
    }

    /// Prepend a context layer (what `.context(..)` does).
    pub fn wrap<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The message layers, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(String::as_str)
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or("error"))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or("error"))?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for cause in &self.chain[1..] {
                write!(f, "\n    {cause}")?;
            }
        }
        Ok(())
    }
}

impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(err: E) -> Error {
        let mut chain = vec![err.to_string()];
        let mut source = err.source();
        while let Some(cause) = source {
            chain.push(cause.to_string());
            source = cause.source();
        }
        Error { chain }
    }
}

/// `anyhow::Result<T>` — `std::result::Result` defaulted to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context(..)` / `.with_context(..)`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: StdError + Send + Sync + 'static> Context<T> for Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| Error::from(e).wrap(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::from(e).wrap(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an [`Error`] unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!("condition failed: {}", stringify!($cond));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing file")
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        let err = inner().unwrap_err();
        assert!(err.to_string().contains("missing file"));
    }

    #[test]
    fn context_prepends_layers() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let err = r.context("reading config").unwrap_err();
        assert_eq!(err.to_string(), "reading config");
        assert_eq!(format!("{err:#}"), "reading config: missing file");
        assert!(format!("{err:?}").contains("Caused by"));
    }

    #[test]
    fn option_context() {
        let none: Option<u32> = None;
        let err = none.with_context(|| format!("slot {}", 3)).unwrap_err();
        assert_eq!(err.to_string(), "slot 3");
        assert_eq!(Some(5).context("never").unwrap(), 5);
    }

    #[test]
    fn macros_work() {
        fn f(x: i32) -> Result<i32> {
            ensure!(x >= 0, "negative input {x}");
            ensure!(x != 1);
            if x == 2 {
                bail!("two is right out");
            }
            Ok(x)
        }
        assert_eq!(f(3).unwrap(), 3);
        assert!(f(-1).unwrap_err().to_string().contains("negative"));
        assert!(f(1).unwrap_err().to_string().contains("condition failed"));
        assert!(f(2).unwrap_err().to_string().contains("two"));
        let e = anyhow!("code {}", 7);
        assert_eq!(e.to_string(), "code 7");
    }

    #[test]
    fn error_is_send_and_sync() {
        fn assert_send_sync<T: Send + Sync>() {}
        assert_send_sync::<Error>();
    }
}
