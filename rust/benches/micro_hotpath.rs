//! Microbenchmarks of the hot paths (§Perf, L3): event queue push/pop
//! (calendar vs the reference heap, recorded to `BENCH_engine.json` at
//! the repo root — the measured backbone of the hot-path campaign),
//! argmin-tree updates, probe placement over the SoA hot-field mirror
//! vs the reference struct reads, zero-alloc revoke churn with the
//! pool hit/miss counters, task stealing, and the PJRT analytics
//! invocation latency (the epoch path).
//!
//! `cargo bench --offline --bench micro_hotpath`

use cloudcoaster::benchkit::{bench, black_box, fmt_ns, BenchResult};
use cloudcoaster::cluster::{Cluster, QueuePolicy};
use cloudcoaster::coordinator::report::artifacts_dir;
use cloudcoaster::metrics::Recorder;
use cloudcoaster::runtime::AnalyticsEngine;
use cloudcoaster::sched::probe::{assign_least_loaded, filter_long, sample_from_pool, ProbeBuffers};
use cloudcoaster::sim::{Engine, Event, Rng};
use cloudcoaster::util::{JobId, MinTree, ServerRef, TaskRef};

fn json_entry(name: &str, r: &BenchResult) -> String {
    format!(
        "    {{\"name\": \"{name}\", \"median_ns\": {:.0}, \"mean_ns\": {:.0}, \"std_ns\": {:.0}, \"n\": {}}}",
        r.median_ns(),
        r.mean_ns(),
        r.std_ns(),
        r.samples_ns.len()
    )
}

fn mk_engine(reference: bool) -> Engine {
    // Both pre-sized to the same realistic pending-event depth.
    if reference {
        Engine::reference_with_capacity(8192)
    } else {
        Engine::with_capacity(8192)
    }
}

fn bench_event_queue(entries: &mut Vec<String>) {
    // Throughput of schedule+pop on a queue with realistic depth.
    let n = 100_000u64;
    let r = bench("micro/engine_push_pop_100k", 1, 10, || {
        let mut e = Engine::new();
        let mut rng = Rng::new(1);
        for _ in 0..n {
            e.schedule(rng.f64() * 1e6, Event::Snapshot);
        }
        while e.pop().is_some() {}
        black_box(e.processed());
    });
    let evps = 2.0 * n as f64 / (r.median_ns() / 1e9);
    println!("  -> {:.1}M event-ops/s (push+pop)", evps / 1e6);
    entries.push(json_entry("engine_push_pop_100k", &r));
}

/// Steady-state MMPP-shaped churn at 1e6 events: one pop, one push at
/// the popped clock plus an exponential gap whose mean flips between a
/// calm and a burst phase (×100 rate), with an occasional far-future
/// push (the revocation-horizon shape that exercises the overflow
/// rung). Calendar vs the reference `BinaryHeap` — the before/after
/// pair for the calendar-queue tentpole.
fn bench_engine_churn(entries: &mut Vec<String>) {
    let n = 1_000_000u64;
    for (label, reference) in [
        ("engine_churn_mmpp_1e6_calendar", false),
        ("engine_churn_mmpp_1e6_heap_before", true),
    ] {
        let r = bench(&format!("micro/{label}"), 1, 5, || {
            let mut e = mk_engine(reference);
            let mut rng = Rng::new(9);
            for _ in 0..4096 {
                e.schedule(rng.exponential(50.0), Event::Snapshot);
            }
            let mut burst = false;
            let mut ops = 0u64;
            while ops < n {
                let (t, _) = e.pop().expect("steady-state queue drained");
                if ops % 2048 == 0 {
                    burst = !burst;
                }
                let mean = if burst { 0.4 } else { 40.0 };
                e.schedule(t + rng.exponential(mean), Event::Snapshot);
                if ops % 8192 == 0 {
                    e.schedule(t + 1e7 + rng.f64() * 1e7, Event::Snapshot);
                }
                ops += 2;
            }
            black_box(e.processed());
        });
        let evps = n as f64 / (r.median_ns() / 1e9);
        println!("  -> {:.1}M event-ops/s ({label})", evps / 1e6);
        entries.push(json_entry(label, &r));
    }
}

/// Same-timestamp burst storms (~1e6 events in runs of 64 ties):
/// scheduled, then drained via `pop_batch` on both engines, plus a
/// per-pop drain as the before-side of the batch-dispatch change.
fn bench_engine_burst(entries: &mut Vec<String>) {
    let timestamps = 16_384u64;
    let per = 64u64;
    for (label, reference, batched) in [
        ("engine_burst64_pop_batch_calendar", false, true),
        ("engine_burst64_pop_batch_heap", true, true),
        ("engine_burst64_pop_single_before", false, false),
    ] {
        let r = bench(&format!("micro/{label}"), 1, 5, || {
            let mut e = mk_engine(reference);
            let mut rng = Rng::new(13);
            for ts in 0..timestamps {
                let t = ts as f64 + rng.f64() * 0.25;
                for _ in 0..per {
                    e.schedule(t, Event::Snapshot);
                }
            }
            if batched {
                let mut batch = Vec::new();
                while e.pop_batch(&mut batch).is_some() {
                    black_box(batch.len());
                }
            } else {
                while e.pop().is_some() {}
            }
            black_box(e.processed());
        });
        let evps = 2.0 * (timestamps * per) as f64 / (r.median_ns() / 1e9);
        println!("  -> {:.1}M event-ops/s ({label})", evps / 1e6);
        entries.push(json_entry(label, &r));
    }
}

/// Record the engine medians to `BENCH_engine.json` (repo root), the
/// first measured point of the hot-path campaign's trajectory. The
/// committed file carries a placeholder status until a toolchain
/// regenerates it; this overwrites it with measured numbers.
fn write_engine_json(entries: &[String]) {
    let json = format!(
        "{{\n  \"bench\": \"micro_hotpath (engine)\",\n  \"status\": \"measured\",\n  \
         \"results\": [\n{}\n  ]\n}}\n",
        entries.join(",\n")
    );
    let out = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_engine.json");
    std::fs::write(out, &json).expect("write BENCH_engine.json");
    println!("wrote {out}");
}

fn bench_mintree() {
    let mut tree = MinTree::new(3920);
    let mut rng = Rng::new(2);
    let r = bench("micro/mintree_update_argmin_x1000", 10, 20, || {
        for _ in 0..1000 {
            let i = rng.below(3920) as usize;
            tree.update(i, rng.f64() * 1e4);
            black_box(tree.argmin());
        }
    });
    println!("  -> {} per update+argmin", fmt_ns(r.median_ns() / 1000.0));
}

/// Probe sampling + least-loaded assignment, once over the dense SoA
/// hot-field mirror and once over the reference struct reads
/// (`soa_hot_fields` off) — the read-path before/after pair of
/// hot-path campaign part 2. Same cluster shape, same RNG seed; the
/// placements are bit-identical, only the memory traffic differs.
fn bench_probe_placement(entries: &mut Vec<String>) {
    for (label, soa) in
        [("probe_place_soa_dense", true), ("probe_place_struct_before", false)]
    {
        let mut cluster = Cluster::new(3920, 80, QueuePolicy::Fifo);
        cluster.set_soa_hot_fields(soa);
        let mut engine = Engine::new();
        let mut rec = Recorder::new(3.0);
        let mut rng = Rng::new(3);
        // Pre-load some servers.
        for i in 0..2000u32 {
            let t = cluster.add_task(JobId(0), 100.0, i % 5 == 0, 0.0);
            cluster.enqueue(t, ServerRef::initial(i), &mut engine, &mut rec);
        }
        let pool: Vec<ServerRef> = cluster.general.clone();
        let mut buf = ProbeBuffers::new();
        let mut out = Vec::new();
        let costs = vec![30.0f64; 20];
        let r = bench(&format!("micro/{label}_40probes"), 100, 20, || {
            buf.candidates.clear();
            sample_from_pool(&pool, 40, &cluster, &mut rng, &mut buf);
            filter_long(&cluster, &mut buf);
            assign_least_loaded(&cluster, &costs, &mut buf, &mut out);
            black_box(out.len());
        });
        println!(
            "  -> {} per short-job placement (40 probes, {label})",
            fmt_ns(r.median_ns())
        );
        entries.push(json_entry(label, &r));
    }
}

/// Transient revoke churn on the zero-alloc path: `revoke_into` with a
/// caller-owned orphan scratch, server slots recycling through the
/// free list and queue buffers through the capacity pool. The pool
/// counters are recorded next to the timing — at steady state the hit
/// counts track the cycle count and the misses stay bounded by warmup,
/// which is the "zero steady-state allocations" evidence in JSON form.
fn bench_revoke_pool(entries: &mut Vec<String>) {
    let mut cluster = Cluster::new(16, 4, QueuePolicy::Fifo);
    let mut engine = Engine::new();
    let mut rec = Recorder::new(3.0);
    let mut orphans: Vec<TaskRef> = Vec::new();
    let mut now = 0.0f64;
    let cycles = 500u64;
    let r = bench("micro/revoke_into_pooled_x500", 1, 10, || {
        for _ in 0..cycles {
            let sid = cluster.request_transient(now);
            cluster.transient_ready(sid, now, &mut rec);
            for i in 0..8 {
                let t = cluster.add_task(JobId(i), 50.0, false, now);
                cluster.enqueue(t, sid, &mut engine, &mut rec);
            }
            cluster.revoke_into(sid, now + 1.0, &mut rec, &mut orphans);
            black_box(orphans.len());
            now += 10.0;
        }
    });
    println!(
        "  -> {} per request->load->revoke cycle",
        fmt_ns(r.median_ns() / cycles as f64)
    );
    entries.push(json_entry("revoke_into_pooled_cycle500", &r));
    let p = cluster.pool_stats();
    entries.push(format!(
        "    {{\"name\": \"revoke_pool_counters\", \"server_slot_hits\": {}, \
         \"server_slot_misses\": {}, \"queue_buf_hits\": {}, \"queue_buf_misses\": {}}}",
        p.server_slot_hits, p.server_slot_misses, p.queue_buf_hits, p.queue_buf_misses
    ));
}

fn bench_steal() {
    let r = bench("micro/steal_batch8", 10, 20, || {
        let mut cluster = Cluster::new(16, 2, QueuePolicy::Fifo);
        let mut engine = Engine::new();
        let mut rec = Recorder::new(1.0);
        let victim = cluster.short_reserved[0];
        for i in 0..64 {
            let t = cluster.add_task(JobId(i), 10.0, false, 0.0);
            cluster.enqueue(t, victim, &mut engine, &mut rec);
        }
        let thief = cluster.short_reserved[1];
        black_box(cluster.steal_short_tasks(victim, thief, 8, &mut engine, &mut rec));
    });
    println!("  -> {} per steal (incl. setup)", fmt_ns(r.median_ns()));
}

fn bench_analytics() {
    let mut engine = AnalyticsEngine::auto(&artifacts_dir());
    let name = engine.as_dyn().name().to_string();
    let mut rng = Rng::new(4);
    let n = 4000;
    let rw: Vec<f32> = (0..n).map(|_| (rng.f64() * 500.0) as f32).collect();
    let lc: Vec<f32> = (0..n).map(|_| rng.below(2) as f32).collect();
    let ql: Vec<f32> = (0..n).map(|_| rng.below(10) as f32).collect();
    let act = vec![1.0f32; n];
    bench(&format!("micro/{name}_cluster_state_4000srv"), 2, 10, || {
        black_box(engine.as_dyn().cluster_state(&rw, &lc, &ql, &act).unwrap());
    });
    let delays: Vec<f32> = (0..100_000).map(|_| rng.exponential(200.0) as f32).collect();
    let edges: Vec<f32> = (0..512).map(|i| i as f32 * 10.0).collect();
    bench(&format!("micro/{name}_delay_cdf_100k"), 1, 5, || {
        black_box(engine.as_dyn().delay_cdf(&delays, &edges).unwrap());
    });
}

fn main() {
    let mut engine_entries: Vec<String> = Vec::new();
    bench_event_queue(&mut engine_entries);
    bench_engine_churn(&mut engine_entries);
    bench_engine_burst(&mut engine_entries);
    bench_probe_placement(&mut engine_entries);
    bench_revoke_pool(&mut engine_entries);
    write_engine_json(&engine_entries);
    bench_mintree();
    bench_steal();
    bench_analytics();
}
