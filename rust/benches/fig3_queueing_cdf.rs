//! Bench: regenerate **Figure 3** — CDFs of short-task queueing delay for
//! the Eagle baseline and CloudCoaster at r = 1, 2, 3 — on the reduced
//! bench scale, and time one full simulation run.
//!
//! `cargo bench --offline --bench fig3_queueing_cdf`

mod bench_common;

use cloudcoaster::benchkit::{bench, black_box};
use cloudcoaster::coordinator::report::{build_workload, fig3_markdown};
use cloudcoaster::coordinator::runner::simulate;
use cloudcoaster::coordinator::sweep::paper_sweep;
use cloudcoaster::sched::Hybrid;

fn main() {
    let base = bench_common::bench_base();
    let reports = paper_sweep(&base, &[1.0, 2.0, 3.0]).unwrap();
    println!("== Figure 3 (bench scale: 1000 servers, 6h) ==");
    println!("{}", fig3_markdown(&reports));
    println!("CDF probe (delay <= 60s fraction):");
    for rep in &reports {
        let idx = rep.cdf.edges.partition_point(|&e| e <= 60.0);
        println!(
            "  {:<20} {:.3}",
            rep.name,
            rep.cdf.values[idx.saturating_sub(1).min(rep.cdf.values.len() - 1)]
        );
    }

    // Timing: one full baseline simulation (the core DES workload).
    let workload = build_workload(&base).unwrap();
    let sim_cfg = {
        let mut c = base.clone();
        c.scheduler = cloudcoaster::coordinator::config::SchedulerKind::Eagle;
        c.to_sim_config()
    };
    bench("fig3/eagle_simulation_6h_1000srv", 1, 5, || {
        let mut sched = Hybrid::eagle(2.0);
        black_box(simulate(&workload, &mut sched, &sim_cfg));
    });
    let cc_cfg = base.to_sim_config();
    bench("fig3/cloudcoaster_simulation_6h_1000srv", 1, 5, || {
        let mut sched = Hybrid::cloudcoaster(2.0);
        black_box(simulate(&workload, &mut sched, &cc_cfg));
    });
}
