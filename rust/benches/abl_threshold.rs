//! Ablation bench: sensitivity to the long-load-ratio threshold `L_r^T`
//! (DESIGN.md exp `abl-thresh`). The paper fixes L_r^T = 0.95; this
//! sweep shows the delay/cost trade-off around that choice.
//!
//! `cargo bench --offline --bench abl_threshold`

mod bench_common;

use cloudcoaster::benchkit::bench;
use cloudcoaster::coordinator::sweep::{run_sweep_parallel, threshold_points, threshold_sweep};

fn main() {
    let base = bench_common::bench_base();
    let threads = bench_common::default_threads();
    let thresholds = [0.5, 0.75, 0.9, 0.95, 0.99];
    let reports =
        run_sweep_parallel(&base, &threshold_points(&base, &thresholds), threads).unwrap();
    println!("== Ablation: L_r^T sweep (bench scale) ==");
    println!(
        "{:>10} {:>12} {:>12} {:>14} {:>12}",
        "L_r^T", "mean delay", "p99 delay", "avg transients", "requested"
    );
    for (t, rep) in thresholds.iter().zip(&reports) {
        println!(
            "{:>10.2} {:>11.1}s {:>11.1}s {:>14.1} {:>12}",
            t,
            rep.short_delay.mean,
            rep.short_delay.p99,
            rep.avg_transients,
            rep.transients_requested
        );
    }
    // Expected shape: lower threshold -> more transients -> lower delay,
    // higher cost. Sanity-check monotonicity of the cost side.
    assert!(
        reports.first().unwrap().avg_transients >= reports.last().unwrap().avg_transients,
        "lower threshold should hold at least as many transients"
    );

    bench("abl_threshold/one_run", 0, 3, || {
        let _ = threshold_sweep(&base, &[0.95]).unwrap();
    });
}
