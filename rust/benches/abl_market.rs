//! Ablation bench: dynamic spot-market pricing (DESIGN.md exp
//! `abl-market`). The paper assumes fixed 1/r pricing and zero
//! revocations; this sweep runs CloudCoaster against a regime-switching
//! price process at different bid levels — low bids mean cheaper servers
//! but price-crossing revocations and unavailable windows.
//!
//! `cargo bench --offline --bench abl_market`

mod bench_common;

use cloudcoaster::benchkit::bench;
use cloudcoaster::coordinator::sweep::{bid_points, bid_sweep, run_sweep_parallel};

fn main() {
    let base = bench_common::bench_base();
    let threads = bench_common::default_threads();
    let bids = [None, Some(2.0), Some(0.50), Some(0.35)];
    let reports = run_sweep_parallel(&base, &bid_points(&base, &bids), threads).unwrap();
    println!("== Ablation: spot bid sweep (bench scale) ==");
    println!(
        "{:>12} {:>12} {:>12} {:>10} {:>12} {:>12}",
        "bid", "mean delay", "p99 delay", "revoked", "rescheduled", "avg transnt"
    );
    for rep in &reports {
        println!(
            "{:>12} {:>11.1}s {:>11.1}s {:>10} {:>12} {:>12.1}",
            rep.name,
            rep.short_delay.mean,
            rep.short_delay.p99,
            rep.transients_revoked,
            rep.tasks_rescheduled,
            rep.avg_transients,
        );
    }
    // Fixed pricing never revokes; a bid at/above on-demand survives all
    // but the rarest spikes; tight bids churn.
    assert_eq!(reports[0].transients_revoked, 0);
    assert!(
        reports[3].transients_revoked >= reports[1].transients_revoked,
        "tight bid should revoke at least as much as a high bid"
    );

    bench("abl_market/bid_0.5_run", 0, 3, || {
        let _ = bid_sweep(&base, &[Some(0.5)]).unwrap();
    });
}
