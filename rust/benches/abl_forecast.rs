//! Ablation bench: reactive (§3.2) vs predictive resizing (DESIGN.md exp
//! `abl-forecast`). The predictive mode forecasts l_r one
//! provisioning-delay ahead through the AOT-compiled `lr_forecast`
//! artifact (Holt level+trend over the snapshot history) and
//! pre-provisions, hiding the 120 s lag behind the crowding trend.
//!
//! `cargo bench --offline --bench abl_forecast`

mod bench_common;

use cloudcoaster::benchkit::bench;
use cloudcoaster::coordinator::sweep::{forecast_points, forecast_sweep, run_sweep_parallel};

fn main() {
    let base = bench_common::bench_base();
    let threads = bench_common::default_threads();
    let reports = run_sweep_parallel(&base, &forecast_points(&base), threads).unwrap();
    println!("== Ablation: reactive vs predictive resizing (bench scale) ==");
    println!(
        "{:>24} {:>12} {:>12} {:>14} {:>11}",
        "mode", "mean delay", "p99 delay", "avg transients", "requested"
    );
    for rep in &reports {
        println!(
            "{:>24} {:>11.1}s {:>11.1}s {:>14.1} {:>11}",
            rep.name,
            rep.short_delay.mean,
            rep.short_delay.p99,
            rep.avg_transients,
            rep.transients_requested
        );
    }
    let reactive = &reports[0];
    let predictive = &reports[1];
    println!(
        "\npredictive vs reactive: {:.2}X mean delay, {:+.1} avg transients",
        reactive.short_delay.mean / predictive.short_delay.mean.max(1e-9),
        predictive.avg_transients - reactive.avg_transients,
    );
    // The predictive mode must at minimum not lose work and must hold at
    // least as many transients (it pre-provisions).
    assert!(predictive.avg_transients >= reactive.avg_transients * 0.9);

    bench("abl_forecast/predictive_run", 0, 3, || {
        let _ = forecast_sweep(&base).unwrap();
    });
}
