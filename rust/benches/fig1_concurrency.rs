//! Bench: regenerate **Figure 1** — theoretical concurrent tasks on the
//! Google-like trace (100 s then 4 h averaging) — and time the interval
//! counting analytics (XLA artifact vs native reference).
//!
//! `cargo bench --offline --bench fig1_concurrency`

use cloudcoaster::benchkit::{bench, black_box};
use cloudcoaster::coordinator::report::artifacts_dir;
use cloudcoaster::metrics::TimeSeries;
use cloudcoaster::runtime::{Analytics, AnalyticsEngine, NativeAnalytics};
use cloudcoaster::sim::Rng;
use cloudcoaster::trace::synth::{google_like, GoogleLikeParams};

fn main() {
    let mut params = GoogleLikeParams::default();
    params.horizon = 2.0 * 86_400.0; // 2 days is plenty for a bench
    let workload = google_like(&params, &mut Rng::new(23));
    let mut starts = Vec::new();
    let mut ends = Vec::new();
    for job in &workload.jobs {
        for &d in &job.task_durations {
            starts.push(job.arrival as f32);
            ends.push((job.arrival + d) as f32);
        }
    }
    let n_points = (params.horizon / 100.0) as usize;
    let points: Vec<f32> = (0..n_points.min(2048)).map(|i| i as f32 * 100.0).collect();
    println!(
        "fig1 workload: {} jobs, {} tasks, {} sample points",
        workload.num_jobs(),
        starts.len(),
        points.len()
    );

    let mut engine = AnalyticsEngine::auto(&artifacts_dir());
    let counts = engine.as_dyn().concurrency(&starts, &ends, &points).unwrap();
    let mut fine = TimeSeries::new();
    for (p, c) in points.iter().zip(&counts) {
        fine.push(*p as f64, *c as f64);
    }
    let coarse = fine.rebucket(4.0 * 3600.0);
    let peak = coarse.max();
    let trough = coarse.points.iter().map(|&(_, v)| v).fold(f64::INFINITY, f64::min);
    println!(
        "fig1 series: mean {:.0} tasks, peak/trough {:.1}X (paper: >6X), {} coarse buckets",
        fine.mean(),
        peak / trough.max(1.0),
        coarse.len()
    );

    bench(&format!("fig1/{}_interval_count", engine.as_dyn().name()), 1, 5, || {
        black_box(engine.as_dyn().concurrency(&starts, &ends, &points).unwrap());
    });
    let mut native = NativeAnalytics;
    bench("fig1/native_interval_count", 1, 5, || {
        black_box(native.concurrency(&starts, &ends, &points).unwrap());
    });
}
