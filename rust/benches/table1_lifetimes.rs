//! Bench: regenerate **Table 1** — transient server lifetimes and active
//! counts at r = 1, 2, 3 — on the reduced bench scale.
//!
//! `cargo bench --offline --bench table1_lifetimes`

mod bench_common;

use cloudcoaster::benchkit::bench;
use cloudcoaster::coordinator::report::table1_markdown;
use cloudcoaster::coordinator::sweep::{paper_points, paper_sweep, run_sweep_parallel};

fn main() {
    let base = bench_common::bench_base();
    let reports = paper_sweep(&base, &[1.0, 2.0, 3.0]).unwrap();
    println!("== Table 1 (bench scale) ==");
    println!("{}", table1_markdown(&reports));
    for rep in &reports[1..] {
        let budget_baseline = base.short_partition as f64 * base.p;
        println!(
            "  {:<20} lifetimes below spot MTTF (18h): max {:.1}h; \
             r-norm saving vs {:.0} static: {:.1}%",
            rep.name,
            rep.max_lifetime_h,
            budget_baseline,
            100.0 * (budget_baseline - rep.r_normalized_avg) / budget_baseline,
        );
    }

    bench("table1/full_sweep_4_runs_serial", 0, 3, || {
        let _ = paper_sweep(&base, &[1.0, 2.0, 3.0]).unwrap();
    });
    let threads = bench_common::default_threads();
    let points = paper_points(&base, &[1.0, 2.0, 3.0]);
    bench(&format!("table1/full_sweep_4_runs_{threads}threads"), 0, 3, || {
        let _ = run_sweep_parallel(&base, &points, threads).unwrap();
    });
}
