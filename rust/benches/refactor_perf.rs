//! Before/after micro-benchmark for the PoolIndex + parallel-sweep
//! refactor, recorded to `BENCH_refactor.json` at the repo root so the
//! perf trajectory has a data point per run.
//!
//! Measures:
//! * indexed `least_loaded_general` / `least_loaded_short_reserved`
//!   queries vs the naive linear scans they replaced ("before" is the
//!   scan, re-implemented here verbatim);
//! * steady-state revocation churn through the pooled `revoke_into`
//!   scratch vs the allocating `revoke` wrapper (hot-path campaign
//!   part 2), with the pool hit/miss counters recorded alongside;
//! * a paper-grid sweep executed serially vs fanned out with
//!   `run_sweep_parallel` across all cores.
//!
//! `cargo bench --offline --bench refactor_perf`

mod bench_common;

use cloudcoaster::benchkit::{bench, black_box, BenchResult};
use cloudcoaster::cluster::{Cluster, QueuePolicy};
use cloudcoaster::coordinator::sweep::{paper_points, run_sweep_parallel};
use cloudcoaster::metrics::Recorder;
use cloudcoaster::sim::{Engine, Rng};
use cloudcoaster::util::{JobId, ServerRef, TaskRef};

/// The pre-refactor short-pool scan (what `least_loaded_short_ondemand`
/// and `replace_orphans` did per placement).
fn naive_short_scan(cluster: &Cluster) -> Option<ServerRef> {
    cluster
        .short_reserved
        .iter()
        .copied()
        .filter(|&s| cluster.server(s).accepting())
        .min_by(|&a, &b| {
            cluster.server(a).est_work.total_cmp(&cluster.server(b).est_work)
        })
}

/// The pre-refactor general-pool scan (what a tree-less least-loaded
/// placement costs at paper scale).
fn naive_general_scan(cluster: &Cluster) -> ServerRef {
    *cluster
        .general
        .iter()
        .min_by(|&&a, &&b| cluster.server(a).est_work.total_cmp(&cluster.server(b).est_work))
        .unwrap()
}

fn loaded_cluster(n_general: usize, n_short: usize) -> (Cluster, Engine, Recorder) {
    let mut cluster = Cluster::new(n_general, n_short, QueuePolicy::Fifo);
    let mut engine = Engine::new();
    let mut rec = Recorder::new(3.0);
    let mut rng = Rng::new(7);
    for i in 0..(n_general + n_short) * 2 {
        let sid = ServerRef::initial((i % (n_general + n_short)) as u32);
        let t = cluster.add_task(JobId(0), 1.0 + rng.f64() * 100.0, false, 0.0);
        cluster.enqueue(t, sid, &mut engine, &mut rec);
    }
    (cluster, engine, rec)
}

fn json_entry(name: &str, r: &BenchResult) -> String {
    format!(
        "    {{\"name\": \"{name}\", \"median_ns\": {:.0}, \"mean_ns\": {:.0}, \"std_ns\": {:.0}, \"n\": {}}}",
        r.median_ns(),
        r.mean_ns(),
        r.std_ns(),
        r.samples_ns.len()
    )
}

fn main() {
    let mut entries: Vec<String> = Vec::new();
    let iters = 5000u64;

    // ---- placement queries: indexed vs naive scan -------------------
    {
        let (mut cluster, mut engine, mut rec) = loaded_cluster(3920, 80);

        let r = bench("refactor/least_loaded_general_indexed_x5000", 2, 10, || {
            for _ in 0..iters {
                black_box(cluster.least_loaded_general());
            }
        });
        entries.push(json_entry("least_loaded_general_indexed", &r));

        let r = bench("refactor/least_loaded_general_scan_x5000", 2, 10, || {
            for _ in 0..iters {
                black_box(naive_general_scan(&cluster));
            }
        });
        entries.push(json_entry("least_loaded_general_scan_before", &r));

        let r = bench("refactor/short_pool_indexed_x5000", 2, 10, || {
            for _ in 0..iters {
                black_box(cluster.least_loaded_short_reserved());
            }
        });
        entries.push(json_entry("short_pool_indexed", &r));

        let r = bench("refactor/short_pool_scan_x5000", 2, 10, || {
            for _ in 0..iters {
                black_box(naive_short_scan(&cluster));
            }
        });
        entries.push(json_entry("short_pool_scan_before", &r));

        // Mixed query+update churn (placement hot loop shape).
        let r = bench("refactor/indexed_query_update_x5000", 2, 10, || {
            for _ in 0..iters {
                let sid = cluster.least_loaded_general();
                let t = cluster.add_task(JobId(1), 1.0, false, engine.now());
                cluster.enqueue(t, sid, &mut engine, &mut rec);
                black_box(sid);
            }
        });
        entries.push(json_entry("indexed_query_update_churn", &r));
    }

    // ---- arena churn: recycling vs append-only ----------------------
    // Steady-state enqueue->finish churn through the generational arena:
    // the recycling path pays the free-list push/pop + generation bump,
    // the append-only path pays unbounded Vec growth instead. Tracks the
    // recycle overhead per task (and the memory win is what the CI
    // memory-bound smoke pins).
    for (label, recycle) in
        [("arena_churn_recycling", true), ("arena_churn_append_only", false)]
    {
        let mut cluster = Cluster::new(64, 8, QueuePolicy::Fifo);
        cluster.set_task_recycling(recycle);
        let mut engine = Engine::new();
        let mut rec = Recorder::new(3.0);
        let mut rng = Rng::new(11);
        let r = bench(&format!("refactor/{label}_x5000"), 2, 10, || {
            for i in 0..iters {
                let sid = ServerRef::initial((i % 72) as u32);
                let t = cluster.add_task(JobId(0), 0.5 + rng.f64(), false, engine.now());
                cluster.enqueue(t, sid, &mut engine, &mut rec);
                // Drain one finish per enqueue: steady state, so the
                // recycling arena stays at O(servers) slots.
                if let Some((_, ev)) = engine.pop() {
                    if let cloudcoaster::sim::Event::TaskFinish { server, task } = ev {
                        cluster.on_task_finish(server, task, &mut engine, &mut rec);
                    }
                }
                black_box(t);
            }
        });
        entries.push(json_entry(label, &r));
        // Record the arena footprint each mode ended with (slots, not
        // ns — the memory side of the churn trade).
        entries.push(format!(
            "    {{\"name\": \"{label}_final_slots\", \"slots\": {}, \"peak_resident\": {}}}",
            cluster.task_slots(),
            cluster.peak_resident_tasks()
        ));
    }

    // ---- server-arena churn: recycling vs append-only ---------------
    // Request->ready->drain->retire lifecycle churn: the recycling path
    // reuses one arena slot (+ one index tree slot) per concurrent
    // transient, the append-only path grows both per request.
    for (label, recycle) in
        [("server_churn_recycling", true), ("server_churn_append_only", false)]
    {
        let mut cluster = Cluster::new(16, 4, QueuePolicy::Fifo);
        cluster.set_server_recycling(recycle);
        let mut rec = Recorder::new(3.0);
        let mut now = 0.0f64;
        let r = bench(&format!("refactor/{label}_x2000"), 1, 10, || {
            for _ in 0..2000 {
                let sid = cluster.request_transient(now);
                cluster.transient_ready(sid, now + 120.0, &mut rec);
                if cluster.begin_drain(sid) {
                    cluster.retire(sid, now + 240.0, &mut rec);
                }
                now += 300.0;
                black_box(sid);
            }
        });
        entries.push(json_entry(label, &r));
        entries.push(format!(
            "    {{\"name\": \"{label}_final_slots\", \"slots\": {}, \"peak_resident\": {}}}",
            cluster.server_slots(),
            cluster.peak_resident_servers()
        ));
    }

    // ---- steady-state allocation: pooled scratch vs fresh Vecs ------
    // The zero-alloc campaign's before/after. The same request ->
    // ready -> load -> revoke churn runs once through `revoke_into`
    // with a reused orphan scratch (and the queue-buffer pool behind
    // retire/request underneath), and once through the allocating
    // `revoke` wrapper that returns a fresh Vec per call. The results
    // are identical; the delta is the steady-state allocator traffic
    // on the revocation path. Pool hit/miss counters ride along as the
    // structural evidence (hits track cycles, misses stay at warmup).
    for (label, pooled) in
        [("alloc_steady_state_pooled", true), ("alloc_steady_state_before", false)]
    {
        let mut cluster = Cluster::new(16, 4, QueuePolicy::Fifo);
        let mut engine = Engine::new();
        let mut rec = Recorder::new(3.0);
        let mut scratch: Vec<TaskRef> = Vec::new();
        let mut now = 0.0f64;
        let r = bench(&format!("refactor/{label}_x2000"), 1, 10, || {
            for _ in 0..2000u64 {
                let sid = cluster.request_transient(now);
                cluster.transient_ready(sid, now, &mut rec);
                for i in 0..4 {
                    let t = cluster.add_task(JobId(i), 25.0, false, now);
                    cluster.enqueue(t, sid, &mut engine, &mut rec);
                }
                if pooled {
                    cluster.revoke_into(sid, now + 1.0, &mut rec, &mut scratch);
                    black_box(scratch.len());
                } else {
                    black_box(cluster.revoke(sid, now + 1.0, &mut rec).len());
                }
                now += 10.0;
            }
        });
        entries.push(json_entry(label, &r));
        let p = cluster.pool_stats();
        entries.push(format!(
            "    {{\"name\": \"{label}_pool_counters\", \"server_slot_hits\": {}, \
             \"server_slot_misses\": {}, \"queue_buf_hits\": {}, \"queue_buf_misses\": {}}}",
            p.server_slot_hits, p.server_slot_misses, p.queue_buf_hits, p.queue_buf_misses
        ));
    }

    // ---- federation: event-time merge loop vs sequential runs -------
    // An N=2 pass-through federation interleaves two member event loops
    // through the earliest-next-event merge (peek both engines per
    // step); the baseline runs the same two member configs back to
    // back. The delta is the merge-loop overhead per event.
    {
        use cloudcoaster::coordinator::report::build_workload;
        use cloudcoaster::coordinator::scenario::FederationSpec;
        use cloudcoaster::coordinator::{run_federation, simulate};

        let mut base = bench_common::bench_base();
        if let cloudcoaster::coordinator::config::WorkloadSource::YahooLike(p) =
            &mut base.workload
        {
            p.horizon = 3600.0;
        }
        let spec = FederationSpec { clusters: 2, ..Default::default() };
        let mut fed_cfg = base.clone();
        fed_cfg.federation = Some(spec.clone());

        let r = bench("refactor/federation_merge_2x", 1, 5, || {
            let out = run_federation(&fed_cfg).unwrap();
            black_box(out.runs.len());
        });
        entries.push(json_entry("federation_merge_2x", &r));

        // Baseline: the same two member simulations, run sequentially
        // (identical workloads and seeds, no merge loop between them).
        let members: Vec<_> = (0..2).map(|i| spec.member_config(&base, i)).collect();
        let workloads: Vec<_> =
            members.iter().map(|m| build_workload(m).unwrap()).collect();
        let r = bench("refactor/federation_sequential_baseline_2x", 1, 5, || {
            for (mc, w) in members.iter().zip(&workloads) {
                let mut sched =
                    cloudcoaster::coordinator::report::build_scheduler(mc.scheduler, mc.probe_ratio);
                let res = simulate(w, sched.as_mut(), &mc.to_sim_config());
                black_box(res.events);
            }
        });
        entries.push(json_entry("federation_sequential_baseline_2x", &r));
    }

    // ---- PDES: 64-member federation, serial merge vs windowed -------
    // A wide pass-through federation is the PDES sweet spot: members are
    // uncoupled (no routed feeds, no pooled budget), so the conservative
    // window covers each member's whole run and the only serial work is
    // the start/finish bookkeeping. Reports are bit-identical; the delta
    // is the wall-clock of advancing 64 member worlds on 1 vs N threads.
    {
        use cloudcoaster::coordinator::run_federation;
        use cloudcoaster::coordinator::scenario::FederationSpec;

        let mut base = bench_common::bench_base();
        if let cloudcoaster::coordinator::config::WorkloadSource::YahooLike(p) =
            &mut base.workload
        {
            // 64 members multiply the event volume: shorten each.
            p.horizon = 900.0;
        }
        let threads = bench_common::default_threads();
        for (label, pdes_threads) in
            [("pdes_fed64_serial", 0usize), ("pdes_fed64_parallel", threads)]
        {
            let mut cfg = base.clone();
            cfg.federation =
                Some(FederationSpec { clusters: 64, pdes_threads, ..Default::default() });
            let r = bench(&format!("refactor/{label}"), 1, 5, || {
                let out = run_federation(&cfg).unwrap();
                black_box(out.runs.len());
            });
            entries.push(json_entry(label, &r));
        }
    }

    // ---- event engine: calendar vs reference heap, end-to-end -------
    // The micro numbers live in BENCH_engine.json (micro_hotpath); this
    // is the whole-simulation view of the same swap — identical wiring
    // and workload, only `SimConfig::reference_engine` differs (results
    // are bit-identical; the delta is pure event-queue wall-clock).
    {
        use cloudcoaster::coordinator::report::{build_scheduler, build_workload};
        use cloudcoaster::coordinator::simulate;

        let mut base = bench_common::bench_base();
        if let cloudcoaster::coordinator::config::WorkloadSource::YahooLike(p) =
            &mut base.workload
        {
            p.horizon = 3600.0;
        }
        let w = build_workload(&base).unwrap();
        for (label, reference) in
            [("engine_run_calendar", false), ("engine_run_heap_before", true)]
        {
            let mut cfg = base.to_sim_config();
            cfg.reference_engine = reference;
            let r = bench(&format!("refactor/{label}"), 1, 5, || {
                let mut sched = build_scheduler(base.scheduler, base.probe_ratio);
                let res = simulate(&w, sched.as_mut(), &cfg);
                black_box(res.events);
            });
            entries.push(json_entry(label, &r));
        }
    }

    // ---- sweep: serial vs parallel ----------------------------------
    let mut base = bench_common::bench_base();
    // Shrink to keep the bench under a minute while preserving dynamics.
    if let cloudcoaster::coordinator::config::WorkloadSource::YahooLike(p) =
        &mut base.workload
    {
        p.horizon = 2.0 * 3600.0;
    }
    let points = paper_points(&base, &[1.0, 2.0, 3.0]);
    let threads = bench_common::default_threads();

    let serial = bench("refactor/sweep_4runs_serial", 0, 3, || {
        let _ = run_sweep_parallel(&base, &points, 1).unwrap();
    });
    entries.push(json_entry("sweep_4runs_serial", &serial));

    let parallel = bench(&format!("refactor/sweep_4runs_{threads}threads"), 0, 3, || {
        let _ = run_sweep_parallel(&base, &points, threads).unwrap();
    });
    entries.push(json_entry("sweep_4runs_parallel", &parallel));

    let speedup = serial.median_ns() / parallel.median_ns().max(1.0);
    println!("\nsweep parallel speedup: {speedup:.2}x on {threads} threads");

    // ---- record ------------------------------------------------------
    let json = format!(
        "{{\n  \"bench\": \"refactor_perf\",\n  \"threads\": {threads},\n  \
         \"sweep_parallel_speedup\": {speedup:.3},\n  \"results\": [\n{}\n  ]\n}}\n",
        entries.join(",\n")
    );
    let out = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_refactor.json");
    std::fs::write(out, &json).expect("write BENCH_refactor.json");
    println!("wrote {out}");
}
