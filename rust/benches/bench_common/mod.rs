//! Shared configuration for the bench binaries: a reduced-scale but
//! dynamics-preserving version of the paper setup (1000 servers / 6 h
//! horizon instead of 4000 / 24 h) so each bench finishes in seconds
//! while keeping the crowded-regime behaviour. The full-scale run lives
//! in `examples/paper_eval.rs`.

use cloudcoaster::coordinator::config::{ExperimentConfig, WorkloadSource};
use cloudcoaster::trace::synth::YahooLikeParams;

/// Worker threads for grid fan-out. (`allow(dead_code)`: each bench
/// binary compiles this module independently and not all of them sweep.)
#[allow(dead_code)]
pub fn default_threads() -> usize {
    cloudcoaster::coordinator::sweep::default_threads()
}

pub fn bench_base() -> ExperimentConfig {
    let mut cfg = ExperimentConfig::paper_defaults();
    cfg.cluster_size = 1000;
    cfg.short_partition = 20;
    let mut p = YahooLikeParams::default();
    p.horizon = 6.0 * 3600.0;
    // Scale arrival rates with the cluster (1/4 of paper scale), dwell
    // times with the horizon so phases still alternate.
    p.short_arrivals.calm_rate /= 4.0;
    p.short_arrivals.burst_rate /= 4.0;
    p.long_arrivals.calm_rate /= 4.0;
    p.long_arrivals.burst_rate /= 4.0;
    p.long_arrivals.calm_dwell /= 4.0;
    p.long_arrivals.burst_dwell /= 4.0;
    cfg.workload = WorkloadSource::YahooLike(p);
    cfg
}
