//! Ablation bench: behaviour under forced revocations (DESIGN.md exp
//! `abl-revoke`). The paper's evaluation never experiences a revocation
//! (lifetimes ≪ MTTF); this sweep injects MTTF ∈ {1 h, 4 h, ∞} and shows
//! the §3.3 duplicate-copy mechanism keeping the workload lossless.
//!
//! `cargo bench --offline --bench abl_revocation`

mod bench_common;

use cloudcoaster::benchkit::bench;
use cloudcoaster::coordinator::sweep::{revocation_points, revocation_sweep, run_sweep_parallel};

fn main() {
    let base = bench_common::bench_base();
    let threads = bench_common::default_threads();
    let mttfs = [None, Some(4.0 * 3600.0), Some(3600.0)];
    let reports = run_sweep_parallel(&base, &revocation_points(&base, &mttfs), threads).unwrap();
    println!("== Ablation: revocation MTTF sweep (bench scale) ==");
    println!(
        "{:>12} {:>12} {:>12} {:>10} {:>14}",
        "mttf", "mean delay", "p99 delay", "revoked", "rescheduled"
    );
    for rep in &reports {
        println!(
            "{:>12} {:>11.1}s {:>11.1}s {:>10} {:>14}",
            rep.name,
            rep.short_delay.mean,
            rep.short_delay.p99,
            rep.transients_revoked,
            rep.tasks_rescheduled
        );
    }
    assert_eq!(reports[0].transients_revoked, 0, "mttf=inf must never revoke");
    // Harsher market -> at least as many revocations.
    assert!(reports[2].transients_revoked >= reports[1].transients_revoked);

    bench("abl_revocation/mttf_1h_run", 0, 3, || {
        let _ = revocation_sweep(&base, &[Some(3600.0)]).unwrap();
    });
}
