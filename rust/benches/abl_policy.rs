//! Ablation bench: the §3.3 asymmetric grow/shrink policy vs. symmetric
//! alternatives (DESIGN.md exp `abl-policy`): paper (aggressive add,
//! one drain per cooldown), paper-literal (no cooldown), symmetric
//! aggressive (drain as fast as add), and slow-add.
//!
//! `cargo bench --offline --bench abl_policy`

mod bench_common;

use cloudcoaster::benchkit::bench;
use cloudcoaster::coordinator::sweep::{policy_points, policy_sweep, run_sweep_parallel};

fn main() {
    let base = bench_common::bench_base();
    let threads = bench_common::default_threads();
    let reports = run_sweep_parallel(&base, &policy_points(&base), threads).unwrap();
    println!("== Ablation: resize-policy sweep (bench scale) ==");
    println!(
        "{:>28} {:>12} {:>12} {:>12} {:>11}",
        "policy", "mean delay", "p99 delay", "avg transnt", "requested"
    );
    for rep in &reports {
        println!(
            "{:>28} {:>11.1}s {:>11.1}s {:>12.1} {:>11}",
            rep.name,
            rep.short_delay.mean,
            rep.short_delay.p99,
            rep.avg_transients,
            rep.transients_requested
        );
    }
    // The no-cooldown literal policy must churn more than the paper
    // policy (more requests for the same workload).
    assert!(
        reports[1].transients_requested >= reports[0].transients_requested,
        "cooldown should reduce churn"
    );

    bench("abl_policy/paper_run", 0, 3, || {
        let _ = policy_sweep(&base).map(|r| r.len()).unwrap();
    });
}
