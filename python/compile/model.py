"""L2: the jax compute graphs the rust coordinator invokes via PJRT.

Three entry points, each AOT-lowered by ``aot.py`` to a fixed-shape HLO
text artifact (shapes in ``shapes.py``, mirrored to rust via
``artifacts/manifest.json``):

  * ``cluster_state``  — one fused pass over the padded server vector:
    probe scores + global stats + the long-load ratio ``l_r`` (§3.2).
  * ``concurrency``    — Figure 1: concurrent tasks at bucket sample
    points for one chunk of task intervals (rust accumulates chunks).
  * ``delay_cdf``      — Figure 3: cumulative histogram + normalised CDF
    of short-task queueing delays for one chunk of samples.

Each function calls the Layer-1 Pallas kernels (interpret=True, so the
lowered HLO is plain ops runnable on the CPU PJRT client) and does only
cheap scalar epilogue work here, keeping the heavy pass fused and single.
"""

import jax.numpy as jnp

from .kernels.delay_hist import delay_hist
from .kernels.interval_count import interval_count
from .kernels.lr_forecast import lr_forecast
from .kernels.server_scan import server_scan


def cluster_state(remaining_work, long_counts, queue_len, active):
    """-> (scores f32[S], stats f32[4], l_r f32[1]).

    stats = [n_long_servers, total_backlog, total_queued, n_active].
    l_r = n_long_servers / max(n_active, 1) — the paper's long-load ratio.
    """
    scores, stats = server_scan(remaining_work, long_counts, queue_len, active)
    l_r = stats[0] / jnp.maximum(stats[3], 1.0)
    return scores, stats, l_r.reshape((1,))


def concurrency(starts, ends, bucket_times):
    """-> counts f32[B]: concurrent tasks at each bucket sample point."""
    return (interval_count(starts, ends, bucket_times),)


def forecast(history, horizon_steps):
    """-> f32[3] = [forecast l_r, level, slope] (predictive resizing)."""
    return (lr_forecast(history, horizon_steps),)


def delay_cdf(delays, edges, n_valid):
    """-> (counts f32[E], cdf f32[E]).

    ``n_valid`` (f32[1]) is the number of real (non-padding) samples;
    padding samples carry PAD_SENTINEL and never land below an edge.
    """
    counts = delay_hist(delays, edges)
    cdf = counts / jnp.maximum(n_valid[0], 1.0)
    return counts, cdf
