"""L1 Pallas kernel: queueing-delay cumulative histogram (Figure 3
analytics).

Given delay samples ``d_i`` and CDF evaluation edges ``e_j``, computes
``counts[j] = |{ i : d_i <= e_j }|`` — the unnormalised empirical CDF of
short-task queueing delay. The L2 wrapper divides by the valid-sample
count to produce the CDF the paper plots in Figure 3.

Same tiled compare-and-accumulate shape as ``interval_count``: grid =
(edge tiles x delay tiles), the per-edge accumulator block is revisited
across the inner (delay) reduction dimension. Padding samples use
``d = PAD_SENTINEL`` so they fall beyond every finite edge.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..shapes import DELAY_BLOCK, EDGE_BLOCK


def _kernel(d_ref, e_ref, o_ref):
    di = pl.program_id(1)  # inner (reduction) dim: delay tile
    d = d_ref[...]
    e = e_ref[...]
    below = d[:, None] <= e[None, :]
    part = jnp.sum(below.astype(jnp.float32), axis=0)

    @pl.when(di == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += part


def delay_hist(delays, edges, *, delay_block=DELAY_BLOCK, edge_block=EDGE_BLOCK):
    """counts[j] = sum_i [delays[i] <= edges[j]], f32."""
    (n,) = delays.shape
    (m,) = edges.shape
    assert n % delay_block == 0, (n, delay_block)
    assert m % edge_block == 0, (m, edge_block)
    grid = (m // edge_block, n // delay_block)
    delay_spec = pl.BlockSpec((delay_block,), lambda ej, di: (di,))
    edge_spec = pl.BlockSpec((edge_block,), lambda ej, di: (ej,))
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[delay_spec, edge_spec],
        out_specs=edge_spec,
        out_shape=jax.ShapeDtypeStruct((m,), jnp.float32),
        interpret=True,
    )(delays, edges)
