"""L1 Pallas kernel: interval-overlap counting (Figure 1 analytics).

Given task intervals ``[start_i, end_i)`` and time-bucket sample points
``t_j``, computes ``counts[j] = |{ i : start_i <= t_j < end_i }|`` — the
number of tasks concurrently running at each sample point, i.e. the
"theoretical number of concurrent tasks" curve of the paper's Figure 1
(unlimited cluster, omniscient scheduler).

Structured as a ``(buckets x tasks)`` tiled compare-and-accumulate: the
grid iterates bucket tiles (outer) x task tiles (inner, the reduction
dimension); each step materialises a ``TASK_BLOCK x BUCKET_BLOCK`` boolean
overlap tile in VMEM (~2 MiB as f32) and reduces it over the task axis
into the per-bucket accumulator block, which is revisited across the inner
grid dimension. Padding tasks use ``start = PAD_SENTINEL`` so they never
overlap any finite sample point.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..shapes import BUCKET_BLOCK, TASK_BLOCK


def _kernel(s_ref, e_ref, t_ref, o_ref):
    ti = pl.program_id(1)  # inner (reduction) dim: task tile
    s = s_ref[...]
    e = e_ref[...]
    t = t_ref[...]
    overlap = (s[:, None] <= t[None, :]) & (e[:, None] > t[None, :])
    part = jnp.sum(overlap.astype(jnp.float32), axis=0)

    @pl.when(ti == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += part


def interval_count(starts, ends, bucket_times, *, task_block=TASK_BLOCK,
                   bucket_block=BUCKET_BLOCK):
    """counts[j] = sum_i [starts[i] <= bucket_times[j] < ends[i]], f32."""
    (tasks,) = starts.shape
    (buckets,) = bucket_times.shape
    assert tasks % task_block == 0, (tasks, task_block)
    assert buckets % bucket_block == 0, (buckets, bucket_block)
    grid = (buckets // bucket_block, tasks // task_block)
    task_spec = pl.BlockSpec((task_block,), lambda bj, ti: (ti,))
    bucket_spec = pl.BlockSpec((bucket_block,), lambda bj, ti: (bj,))
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[task_spec, task_spec, bucket_spec],
        out_specs=bucket_spec,
        out_shape=jax.ShapeDtypeStruct((buckets,), jnp.float32),
        interpret=True,
    )(starts, ends, bucket_times)
