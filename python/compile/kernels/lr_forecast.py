"""L1 Pallas kernel: long-load-ratio forecasting (predictive resizing).

The reactive §3.2 policy pays the full 120 s provisioning delay on every
crowding onset. The predictive extension forecasts ``l_r`` one
provisioning-delay ahead from the sampled history using exponentially
weighted level + trend (Holt's linear method with fixed gains, expressed
as two weighted reductions so it lowers to a single fused pass):

  level  = sum_k w_k x_k / sum_k w_k          with w_k = (1-alpha)^(W-1-k)
  slope  = weighted least-squares slope of x over step index, same weights
  forecast(h) = clip(level + slope * (h + (W-1) - kbar_w), 0, 1)

where ``kbar_w`` is the weighted mean index — so the trend is anchored at
the weighted centre of the window, not at the last sample.

History windows are small (W = 128), so the kernel is a single-block
reduction; it exists to keep the *entire* epoch-path analytics inside one
AOT artifact set rather than for FLOPs.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..shapes import FORECAST_ALPHA, FORECAST_WINDOW


def _kernel(x_ref, h_ref, out_ref):
    x = x_ref[...]
    h = h_ref[0]
    w = x.shape[0]
    k = jnp.arange(w, dtype=jnp.float32)
    weights = (1.0 - FORECAST_ALPHA) ** (w - 1.0 - k)
    wsum = jnp.sum(weights)
    level = jnp.sum(weights * x) / wsum
    kbar = jnp.sum(weights * k) / wsum
    var = jnp.sum(weights * (k - kbar) * (k - kbar))
    cov = jnp.sum(weights * (k - kbar) * (x - level))
    slope = cov / jnp.maximum(var, 1e-9)
    forecast = jnp.clip(level + slope * (h + (w - 1.0) - kbar), 0.0, 1.0)
    out_ref[...] = jnp.stack([forecast, level, slope])


def lr_forecast(history, horizon_steps):
    """history f32[FORECAST_WINDOW], horizon_steps f32[1] ->
    f32[3] = [forecast, level, slope]."""
    (w,) = history.shape
    assert w == FORECAST_WINDOW, (w, FORECAST_WINDOW)
    return pl.pallas_call(
        _kernel,
        grid=(1,),
        in_specs=[
            pl.BlockSpec((w,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((3,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((3,), jnp.float32),
        interpret=True,
    )(history, horizon_steps)
