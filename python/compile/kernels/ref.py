"""Pure-jnp correctness oracles for the Pallas kernels.

These are the semantic ground truth: trivially-correct whole-array
expressions with no tiling, no grid, no accumulator reuse. pytest asserts
``allclose(kernel(x), ref(x))`` across random and adversarial inputs —
this is the core correctness signal for Layer 1.
"""

import jax.numpy as jnp

from ..shapes import ALPHA, PAD_SENTINEL


def server_scan_ref(remaining_work, long_counts, queue_len, active):
    est_wait = remaining_work + ALPHA * queue_len
    scores = jnp.where(active > 0.0, est_wait, PAD_SENTINEL)
    long_servers = jnp.sum(jnp.where((long_counts > 0.0) & (active > 0.0), 1.0, 0.0))
    stats = jnp.stack(
        [
            long_servers,
            jnp.sum(remaining_work * active),
            jnp.sum(queue_len * active),
            jnp.sum(active),
        ]
    )
    return scores, stats


def interval_count_ref(starts, ends, bucket_times):
    overlap = (starts[:, None] <= bucket_times[None, :]) & (
        ends[:, None] > bucket_times[None, :]
    )
    return jnp.sum(overlap.astype(jnp.float32), axis=0)


def delay_hist_ref(delays, edges):
    below = delays[:, None] <= edges[None, :]
    return jnp.sum(below.astype(jnp.float32), axis=0)


def lr_forecast_ref(history, horizon_steps):
    """Holt level+trend forecast; mirrors lr_forecast.py's math."""
    from ..shapes import FORECAST_ALPHA

    x = history
    w = x.shape[0]
    k = jnp.arange(w, dtype=jnp.float32)
    weights = (1.0 - FORECAST_ALPHA) ** (w - 1.0 - k)
    wsum = jnp.sum(weights)
    level = jnp.sum(weights * x) / wsum
    kbar = jnp.sum(weights * k) / wsum
    var = jnp.sum(weights * (k - kbar) ** 2)
    cov = jnp.sum(weights * (k - kbar) * (x - level))
    slope = cov / jnp.maximum(var, 1e-9)
    forecast = jnp.clip(level + slope * (horizon_steps[0] + (w - 1.0) - kbar), 0.0, 1.0)
    return jnp.stack([forecast, level, slope])


def long_load_ratio_ref(long_counts, active):
    """The paper's l_r = N_long / N_total over the active server set."""
    n_long = jnp.sum(jnp.where((long_counts > 0.0) & (active > 0.0), 1.0, 0.0))
    n_total = jnp.maximum(jnp.sum(active), 1.0)
    return n_long / n_total
