"""L1 Pallas kernel: per-server cluster-state scan.

Computes, in one tiled pass over the (padded) server vector:

  * ``scores[s]``   — estimated wait for a probe landing on server ``s``:
                      ``remaining_work + ALPHA * queue_len`` (inactive /
                      padding servers score ``PAD_SENTINEL`` so they are
                      never selected by the coordinator's top-k probe
                      placement).
  * ``stats``       — global reductions ``[n_long_servers, total_backlog,
                      total_queued, n_active]`` used by the transient
                      manager: ``l_r = n_long_servers / n_active`` is the
                      paper's long-load ratio (§3.2).

TPU shaping: the server vector is tiled in ``SERVER_BLOCK`` slices; the
stats accumulator lives in a single output block revisited by every grid
step (initialised at step 0). All accumulation is f32. Run with
``interpret=True`` — on a real TPU this kernel is VPU-bound (compare+add).
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..shapes import ALPHA, PAD_SENTINEL, SERVER_BLOCK


def _kernel(rw_ref, lc_ref, ql_ref, active_ref, score_ref, stats_ref):
    i = pl.program_id(0)
    rw = rw_ref[...]
    lc = lc_ref[...]
    ql = ql_ref[...]
    active = active_ref[...]

    est_wait = rw + ALPHA * ql
    score_ref[...] = jnp.where(active > 0.0, est_wait, PAD_SENTINEL)

    long_servers = jnp.sum(jnp.where((lc > 0.0) & (active > 0.0), 1.0, 0.0))
    backlog = jnp.sum(rw * active)
    queued = jnp.sum(ql * active)
    n_active = jnp.sum(active)
    part = jnp.stack([long_servers, backlog, queued, n_active])

    @pl.when(i == 0)
    def _init():
        stats_ref[...] = jnp.zeros_like(stats_ref)

    stats_ref[...] += part


def server_scan(remaining_work, long_counts, queue_len, active, *, block=SERVER_BLOCK):
    """Tiled server-state scan. All inputs are f32[S] with S % block == 0."""
    (servers,) = remaining_work.shape
    assert servers % block == 0, (servers, block)
    grid = (servers // block,)
    vec_spec = pl.BlockSpec((block,), lambda i: (i,))
    stats_spec = pl.BlockSpec((4,), lambda i: (0,))
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[vec_spec, vec_spec, vec_spec, vec_spec],
        out_specs=[vec_spec, stats_spec],
        out_shape=[
            jax.ShapeDtypeStruct((servers,), jnp.float32),
            jax.ShapeDtypeStruct((4,), jnp.float32),
        ],
        interpret=True,
    )(remaining_work, long_counts, queue_len, active)
