"""Canonical AOT artifact shapes shared by the kernels, the lowering script
and (via artifacts/manifest.json) the rust runtime.

All artifact shapes are fixed at lowering time — the rust coordinator pads
its inputs up to these sizes (padding conventions are per-kernel, see the
kernel docstrings) and streams larger workloads through in chunks.
"""

# cluster_state: per-server analytics over a padded server vector.
SERVERS = 4096  # max servers per snapshot (4000 on-demand + transients fit)
SERVER_BLOCK = 512

# interval_count: concurrent-task counting (Figure 1 analytics).
TASK_CHUNK = 16384  # tasks per kernel invocation; rust accumulates chunks
BUCKETS = 2048  # time buckets per invocation
TASK_BLOCK = 1024
BUCKET_BLOCK = 512

# delay_hist: queueing-delay histogram/CDF (Figure 3 analytics).
DELAY_CHUNK = 16384
EDGES = 512
DELAY_BLOCK = 1024
EDGE_BLOCK = 512

# Probe-score weight: estimated wait = remaining_work + ALPHA * queue_len.
ALPHA = 1.0

# lr_forecast: predictive resizing (Holt level+trend over l_r history).
FORECAST_WINDOW = 128
FORECAST_ALPHA = 0.1  # per-sample EWMA gain

# Padding sentinel for "never counted" task/delay entries. A finite big
# number (not inf) so the compare-and-accumulate stays NaN-free.
PAD_SENTINEL = 1e30

MANIFEST = {
    "cluster_state": {
        "path": "cluster_state.hlo.txt",
        "inputs": [
            {"name": "remaining_work", "shape": [SERVERS], "dtype": "f32"},
            {"name": "long_counts", "shape": [SERVERS], "dtype": "f32"},
            {"name": "queue_len", "shape": [SERVERS], "dtype": "f32"},
            {"name": "active", "shape": [SERVERS], "dtype": "f32"},
        ],
        "outputs": [
            {"name": "scores", "shape": [SERVERS], "dtype": "f32"},
            {"name": "stats", "shape": [4], "dtype": "f32"},
            {"name": "long_load_ratio", "shape": [1], "dtype": "f32"},
        ],
    },
    "interval_count": {
        "path": "interval_count.hlo.txt",
        "inputs": [
            {"name": "starts", "shape": [TASK_CHUNK], "dtype": "f32"},
            {"name": "ends", "shape": [TASK_CHUNK], "dtype": "f32"},
            {"name": "bucket_times", "shape": [BUCKETS], "dtype": "f32"},
        ],
        "outputs": [
            {"name": "counts", "shape": [BUCKETS], "dtype": "f32"},
        ],
    },
    "lr_forecast": {
        "path": "lr_forecast.hlo.txt",
        "inputs": [
            {"name": "history", "shape": [FORECAST_WINDOW], "dtype": "f32"},
            {"name": "horizon_steps", "shape": [1], "dtype": "f32"},
        ],
        "outputs": [
            {"name": "forecast_level_slope", "shape": [3], "dtype": "f32"},
        ],
    },
    "delay_hist": {
        "path": "delay_hist.hlo.txt",
        "inputs": [
            {"name": "delays", "shape": [DELAY_CHUNK], "dtype": "f32"},
            {"name": "edges", "shape": [EDGES], "dtype": "f32"},
            {"name": "n_valid", "shape": [1], "dtype": "f32"},
        ],
        "outputs": [
            {"name": "counts", "shape": [EDGES], "dtype": "f32"},
            {"name": "cdf", "shape": [EDGES], "dtype": "f32"},
        ],
    },
}
