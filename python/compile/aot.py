"""AOT lowering: jax (L2 + L1) -> HLO text artifacts for the rust runtime.

Interchange format is HLO **text**, not a serialized HloModuleProto: jax
>= 0.5 emits protos with 64-bit instruction ids which the xla crate's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids, so text round-trips cleanly. Lowered with
``return_tuple=True`` — the rust side unwraps with ``to_tuple``.

Usage:  cd python && python -m compile.aot --out-dir ../artifacts
"""

import argparse
import hashlib
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model, shapes


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape):
    return jax.ShapeDtypeStruct(tuple(shape), jnp.float32)


ENTRY_POINTS = {
    "cluster_state": model.cluster_state,
    "interval_count": model.concurrency,
    "lr_forecast": model.forecast,
    "delay_hist": model.delay_cdf,
}


def lower_all(out_dir: str) -> dict:
    manifest = {"artifacts": {}}
    for name, meta in shapes.MANIFEST.items():
        fn = ENTRY_POINTS[name]
        arg_specs = [_spec(inp["shape"]) for inp in meta["inputs"]]
        lowered = jax.jit(fn).lower(*arg_specs)
        text = to_hlo_text(lowered)
        path = os.path.join(out_dir, meta["path"])
        with open(path, "w") as f:
            f.write(text)
        manifest["artifacts"][name] = {
            **meta,
            "sha256": hashlib.sha256(text.encode()).hexdigest(),
            "bytes": len(text),
        }
        print(f"lowered {name}: {len(text)} chars -> {path}")
    return manifest


def main() -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--out-dir", default="../artifacts")
    args = parser.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)
    manifest = lower_all(args.out_dir)
    manifest_path = os.path.join(args.out_dir, "manifest.json")
    with open(manifest_path, "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {manifest_path}")


if __name__ == "__main__":
    main()
