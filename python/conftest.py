"""pytest root: run from python/ so `compile` is importable as a package."""

import os
import sys

sys.path.insert(0, os.path.dirname(__file__))
