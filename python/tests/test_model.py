"""L2 shape/semantics tests: model entry points + AOT lowering round-trip."""

import json
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model, shapes
from compile.kernels.ref import long_load_ratio_ref

jax.config.update("jax_platform_name", "cpu")


def _pad(a, n, fill=0.0):
    out = np.full(n, fill, np.float32)
    out[: len(a)] = a
    return jnp.asarray(out)


class TestClusterState:
    def test_long_load_ratio_matches_paper_definition(self):
        # 4000-server cluster, 3800 of them running long tasks: l_r = 0.95,
        # exactly the paper's default threshold scenario.
        S = shapes.SERVERS
        lc = _pad(np.concatenate([np.ones(3800), np.zeros(200)]), S)
        active = _pad(np.ones(4000), S)
        rw = _pad(np.ones(4000) * 10.0, S)
        ql = _pad(np.zeros(4000), S)
        scores, stats, l_r = model.cluster_state(rw, lc, ql, active)
        assert scores.shape == (S,)
        assert stats.shape == (4,)
        np.testing.assert_allclose(float(l_r[0]), 0.95, rtol=1e-6)
        np.testing.assert_allclose(
            float(l_r[0]), float(long_load_ratio_ref(lc, active)), rtol=1e-6
        )

    def test_empty_cluster_ratio_zero(self):
        S = shapes.SERVERS
        z = jnp.zeros(S, jnp.float32)
        _, _, l_r = model.cluster_state(z, z, z, z)
        assert float(l_r[0]) == 0.0


class TestDelayCdf:
    def test_cdf_normalised(self):
        n = shapes.DELAY_CHUNK
        delays = _pad(np.linspace(0, 100, 1000), n, fill=shapes.PAD_SENTINEL)
        edges = jnp.asarray(np.linspace(0, 200, shapes.EDGES), jnp.float32)
        counts, cdf = model.delay_cdf(delays, edges, jnp.asarray([1000.0]))
        assert float(cdf[-1]) == pytest.approx(1.0)
        assert np.all(np.diff(np.asarray(cdf)) >= 0)


class TestAotLowering:
    @pytest.fixture(scope="class")
    def artifacts(self, tmp_path_factory):
        out = tmp_path_factory.mktemp("artifacts")
        manifest = aot.lower_all(str(out))
        return out, manifest

    def test_all_artifacts_written(self, artifacts):
        out, manifest = artifacts
        for name, meta in shapes.MANIFEST.items():
            path = os.path.join(out, meta["path"])
            assert os.path.exists(path), name
            text = open(path).read()
            assert "HloModule" in text
            assert manifest["artifacts"][name]["bytes"] == len(text)

    def test_hlo_text_has_expected_entry_shapes(self, artifacts):
        out, _ = artifacts
        text = open(os.path.join(out, "cluster_state.hlo.txt")).read()
        # Four f32[SERVERS] parameters.
        assert text.count(f"f32[{shapes.SERVERS}]") >= 4

    def test_manifest_roundtrip(self, artifacts):
        out, manifest = artifacts
        path = os.path.join(out, "manifest.json")
        with open(path, "w") as f:
            json.dump(manifest, f)
        loaded = json.load(open(path))
        assert set(loaded["artifacts"]) == set(shapes.MANIFEST)
