"""lr_forecast kernel: Pallas vs ref, plus analytic sanity checks."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels.lr_forecast import lr_forecast
from compile.kernels.ref import lr_forecast_ref
from compile.shapes import FORECAST_WINDOW

jax.config.update("jax_platform_name", "cpu")

W = FORECAST_WINDOW


def run_both(history, h):
    hist = jnp.asarray(history, jnp.float32)
    hs = jnp.asarray([h], jnp.float32)
    return np.asarray(lr_forecast(hist, hs)), np.asarray(lr_forecast_ref(hist, hs))


class TestLrForecast:
    def test_matches_ref_random(self):
        rng = np.random.default_rng(0)
        for _ in range(10):
            hist = rng.uniform(0, 1, W).astype(np.float32)
            got, want = run_both(hist, 2.0)
            np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)

    def test_constant_history_forecasts_constant(self):
        got, _ = run_both(np.full(W, 0.7, np.float32), 5.0)
        forecast, level, slope = got
        assert abs(level - 0.7) < 1e-5
        assert abs(slope) < 1e-6
        assert abs(forecast - 0.7) < 1e-5

    def test_linear_ramp_extrapolates(self):
        # x_k = k/256: slope 1/256 per step; forecast at +h continues it.
        hist = (np.arange(W) / 256.0).astype(np.float32)
        got, _ = run_both(hist, 10.0)
        forecast, _level, slope = got
        assert abs(slope - 1.0 / 256.0) < 1e-5
        expected = (W - 1 + 10.0) / 256.0
        assert abs(forecast - expected) < 2e-3, (forecast, expected)

    def test_forecast_clipped_to_unit_interval(self):
        hist = (np.arange(W) / float(W)).astype(np.float32)  # steep ramp
        got, _ = run_both(hist, 500.0)
        assert got[0] <= 1.0
        got, _ = run_both(hist[::-1].copy(), 500.0)  # steep decline
        assert got[0] >= 0.0

    def test_recent_samples_dominate(self):
        # Old crowding, recent calm: level must sit near the recent value.
        hist = np.concatenate([np.full(W // 2, 0.95), np.full(W // 2, 0.1)]).astype(
            np.float32
        )
        got, _ = run_both(hist, 0.0)
        assert got[1] < 0.3, f"level {got[1]} ignores recency"

    @settings(max_examples=25, deadline=None)
    @given(
        seed=st.integers(0, 2**31 - 1),
        h=st.floats(0.0, 64.0),
    )
    def test_hypothesis_matches_ref(self, seed, h):
        rng = np.random.default_rng(seed)
        hist = rng.uniform(0, 1, W).astype(np.float32)
        got, want = run_both(hist, h)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)
        assert 0.0 <= got[0] <= 1.0
