"""L1 correctness: Pallas kernels vs pure-jnp oracles (ref.py).

Fixed random suites plus hypothesis sweeps over shapes (block-multiple
sizes) and value regimes, including the adversarial edges the simulator
actually produces: zero-length intervals, identical timestamps, padding
sentinels, all-long and all-idle clusters.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.delay_hist import delay_hist
from compile.kernels.interval_count import interval_count
from compile.kernels.ref import (
    delay_hist_ref,
    interval_count_ref,
    long_load_ratio_ref,
    server_scan_ref,
)
from compile.kernels.server_scan import server_scan
from compile.shapes import PAD_SENTINEL

jax.config.update("jax_platform_name", "cpu")


def rng(seed):
    return np.random.default_rng(seed)


# ---------------------------------------------------------------- server_scan


class TestServerScan:
    def _random_inputs(self, seed, servers):
        r = rng(seed)
        rw = jnp.asarray(r.exponential(100.0, servers), jnp.float32)
        lc = jnp.asarray(r.integers(0, 3, servers), jnp.float32)
        ql = jnp.asarray(r.integers(0, 20, servers), jnp.float32)
        active = jnp.asarray(r.integers(0, 2, servers), jnp.float32)
        return rw, lc, ql, active

    @pytest.mark.parametrize("seed", range(5))
    @pytest.mark.parametrize("servers", [512, 1024, 4096])
    def test_matches_ref(self, seed, servers):
        inputs = self._random_inputs(seed, servers)
        scores, stats = server_scan(*inputs)
        scores_r, stats_r = server_scan_ref(*inputs)
        np.testing.assert_allclose(scores, scores_r, rtol=1e-6)
        np.testing.assert_allclose(stats, stats_r, rtol=1e-6)

    def test_all_idle_cluster(self):
        servers = 512
        z = jnp.zeros(servers, jnp.float32)
        active = jnp.ones(servers, jnp.float32)
        scores, stats = server_scan(z, z, z, active)
        assert float(stats[0]) == 0.0  # no long servers
        assert float(stats[3]) == servers
        np.testing.assert_allclose(scores, np.zeros(servers))

    def test_all_long_cluster(self):
        servers = 512
        ones = jnp.ones(servers, jnp.float32)
        _, stats = server_scan(ones * 50.0, ones, ones, ones)
        assert float(stats[0]) == servers  # every server runs a long task
        lr = long_load_ratio_ref(ones, ones)
        assert float(lr) == 1.0

    def test_padding_scores_sentinel(self):
        servers = 512
        r = rng(7)
        rw = jnp.asarray(r.exponential(10.0, servers), jnp.float32)
        active = jnp.zeros(servers, jnp.float32).at[: servers // 2].set(1.0)
        scores, stats = server_scan(rw, rw, rw, active)
        assert np.all(np.asarray(scores[servers // 2 :]) == PAD_SENTINEL)
        assert float(stats[3]) == servers // 2

    @settings(max_examples=20, deadline=None)
    @given(
        seed=st.integers(0, 2**31 - 1),
        blocks=st.integers(1, 8),
    )
    def test_hypothesis_shapes(self, seed, blocks):
        servers = 512 * blocks
        inputs = self._random_inputs(seed, servers)
        scores, stats = server_scan(*inputs)
        scores_r, stats_r = server_scan_ref(*inputs)
        np.testing.assert_allclose(scores, scores_r, rtol=1e-6)
        np.testing.assert_allclose(stats, stats_r, rtol=1e-6)

    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1), block=st.sampled_from([64, 128, 256]))
    def test_block_size_invariance(self, seed, block):
        inputs = self._random_inputs(seed, 1024)
        scores_a, stats_a = server_scan(*inputs, block=block)
        scores_b, stats_b = server_scan(*inputs, block=512)
        np.testing.assert_allclose(scores_a, scores_b, rtol=1e-6)
        np.testing.assert_allclose(stats_a, stats_b, rtol=1e-6)


# ------------------------------------------------------------- interval_count


class TestIntervalCount:
    def _random_intervals(self, seed, tasks, buckets, horizon=10_000.0):
        r = rng(seed)
        starts = r.uniform(0.0, horizon, tasks).astype(np.float32)
        durs = r.exponential(300.0, tasks).astype(np.float32)
        ends = starts + durs
        times = np.linspace(0.0, horizon, buckets, dtype=np.float32)
        return jnp.asarray(starts), jnp.asarray(ends), jnp.asarray(times)

    @pytest.mark.parametrize("seed", range(3))
    @pytest.mark.parametrize("tasks,buckets", [(1024, 512), (4096, 1024), (16384, 2048)])
    def test_matches_ref(self, seed, tasks, buckets):
        s, e, t = self._random_intervals(seed, tasks, buckets)
        got = interval_count(s, e, t)
        want = interval_count_ref(s, e, t)
        np.testing.assert_allclose(got, want)

    def test_zero_length_intervals_never_counted(self):
        s = jnp.linspace(0.0, 100.0, 1024, dtype=jnp.float32)
        t = jnp.linspace(0.0, 100.0, 512, dtype=jnp.float32)
        got = interval_count(s, s, t)  # end == start -> empty interval
        np.testing.assert_allclose(got, np.zeros(512))

    def test_padding_sentinel_never_counted(self):
        s = jnp.full((1024,), PAD_SENTINEL, jnp.float32)
        e = jnp.full((1024,), PAD_SENTINEL, jnp.float32)
        t = jnp.linspace(0.0, 1e6, 512, dtype=jnp.float32)
        got = interval_count(s, e, t)
        np.testing.assert_allclose(got, np.zeros(512))

    def test_single_task_boundary_semantics(self):
        # Interval [10, 20): counted at t=10, not at t=20.
        s = jnp.full((1024,), PAD_SENTINEL, jnp.float32).at[0].set(10.0)
        e = jnp.full((1024,), PAD_SENTINEL, jnp.float32).at[0].set(20.0)
        t = jnp.asarray(
            np.concatenate([[9.0, 10.0, 15.0, 20.0, 21.0], np.full(507, 1e9)]),
            jnp.float32,
        )
        got = np.asarray(interval_count(s, e, t))
        assert list(got[:5]) == [0.0, 1.0, 1.0, 0.0, 0.0]

    @settings(max_examples=15, deadline=None)
    @given(
        seed=st.integers(0, 2**31 - 1),
        task_tiles=st.integers(1, 4),
        bucket_tiles=st.integers(1, 3),
    )
    def test_hypothesis_shapes(self, seed, task_tiles, bucket_tiles):
        tasks, buckets = 1024 * task_tiles, 512 * bucket_tiles
        s, e, t = self._random_intervals(seed, tasks, buckets)
        np.testing.assert_allclose(
            interval_count(s, e, t), interval_count_ref(s, e, t)
        )

    def test_chunk_accumulation_equals_whole(self):
        # The rust runtime streams task chunks and sums counts — verify the
        # decomposition is exact.
        s, e, t = self._random_intervals(11, 4096, 512)
        whole = np.asarray(interval_count(s, e, t))
        parts = sum(
            np.asarray(interval_count(s[i : i + 1024], e[i : i + 1024], t))
            for i in range(0, 4096, 1024)
        )
        np.testing.assert_allclose(whole, parts)


# ----------------------------------------------------------------- delay_hist


class TestDelayHist:
    def _random(self, seed, n, m):
        r = rng(seed)
        delays = jnp.asarray(r.exponential(200.0, n), jnp.float32)
        edges = jnp.asarray(np.sort(r.uniform(0, 2000.0, m)), jnp.float32)
        return delays, edges

    @pytest.mark.parametrize("seed", range(3))
    @pytest.mark.parametrize("n,m", [(1024, 512), (16384, 512)])
    def test_matches_ref(self, seed, n, m):
        d, e = self._random(seed, n, m)
        np.testing.assert_allclose(delay_hist(d, e), delay_hist_ref(d, e))

    def test_cdf_is_monotone_and_complete(self):
        d, e = self._random(3, 4096, 512)
        counts = np.asarray(delay_hist(d, e))
        assert np.all(np.diff(counts) >= 0.0)
        # Final edge above max delay captures every sample.
        e_full = jnp.asarray(
            np.concatenate([np.asarray(e)[:-1], [1e9]]), jnp.float32
        )
        counts_full = np.asarray(delay_hist(d, e_full))
        assert counts_full[-1] == 4096.0

    def test_padding_excluded(self):
        d = jnp.full((1024,), PAD_SENTINEL, jnp.float32).at[:10].set(5.0)
        e = jnp.asarray(np.linspace(0, 100, 512), jnp.float32)
        counts = np.asarray(delay_hist(d, e))
        assert counts[-1] == 10.0

    @settings(max_examples=15, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1), tiles=st.integers(1, 4))
    def test_hypothesis_shapes(self, seed, tiles):
        d, e = self._random(seed, 1024 * tiles, 512)
        np.testing.assert_allclose(delay_hist(d, e), delay_hist_ref(d, e))

    def test_zero_delay_boundary(self):
        # delay == edge counts as "<=" (closed on the right).
        d = jnp.full((1024,), PAD_SENTINEL, jnp.float32).at[0].set(0.0)
        e = jnp.zeros((512,), jnp.float32)
        counts = np.asarray(delay_hist(d, e))
        assert np.all(counts == 1.0)
