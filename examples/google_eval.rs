//! Future-work experiment from the paper's §6: "we first plan to
//! evaluate CloudCoaster using large scale Google cluster traces."
//!
//! Runs the paper's scheduler grid (Eagle baseline + CloudCoaster at
//! r = 1, 2, 3) on the Google-like workload — much heavier task-count
//! tails (1..49,960 tasks/job) and burstier arrivals than the Yahoo-like
//! evaluation trace.
//!
//! ```bash
//! cargo run --release --offline --example google_eval
//! ```

use anyhow::Result;

use cloudcoaster::coordinator::config::{ExperimentConfig, WorkloadSource};
use cloudcoaster::coordinator::report::{fig3_markdown, summary_line, table1_markdown};
use cloudcoaster::coordinator::sweep::paper_sweep;
use cloudcoaster::sim::Rng;
use cloudcoaster::trace::synth::{google_like, GoogleLikeParams};
use cloudcoaster::trace::TraceStats;

fn main() -> Result<()> {
    // The Google-like trace averages only a few hundred concurrent tasks
    // (Figure 1), so the cluster is sized to the trace: 500 servers with
    // a 24-server short partition, and arrivals scaled 3X to load it.
    let mut cfg = ExperimentConfig::paper_defaults();
    cfg.cluster_size = 500;
    cfg.short_partition = 24;
    cfg.threshold = 0.90; // the Google trace is spikier; trigger earlier
    let mut params = GoogleLikeParams::default();
    params.horizon = 2.0 * 86_400.0; // 2 days
    params.arrivals.calm_rate *= 3.0;
    params.arrivals.burst_rate *= 3.0;
    // Heavier long-duration tail so long jobs exist under the 90s cutoff
    // (the Figure-1 defaults skew short; scheduling needs both classes).
    params.dur_mu = 5.4;
    params.dur_sigma = 1.6;
    cfg.workload = WorkloadSource::GoogleLike(params.clone());

    let stats = TraceStats::of(&google_like(&params, &mut Rng::new(cfg.seed)));
    println!("google-like workload: {}", stats.summary());

    let reports = paper_sweep(&cfg, &[1.0, 2.0, 3.0])?;
    println!("\n== Google-trace evaluation (paper §6 future work) ==");
    println!("{}", fig3_markdown(&reports));
    println!("{}", table1_markdown(&reports));
    for rep in &reports {
        println!("{}", summary_line(rep));
    }

    let base = &reports[0];
    let r3 = reports.last().unwrap();
    println!(
        "\nCloudCoaster r=3 on the Google-like trace: {:.2}X avg short-delay improvement \
         ({:.1}s -> {:.1}s), {:.1} avg transients.",
        base.short_delay.mean / r3.short_delay.mean.max(1e-9),
        base.short_delay.mean,
        r3.short_delay.mean,
        r3.avg_transients,
    );
    Ok(())
}
