//! Figure 1 reproduction: "theoretical number of concurrent tasks" on
//! the Google-like trace, computed through the AOT-compiled interval
//! counting kernel (L1 Pallas via PJRT) and averaged exactly as the
//! paper does — 100-second buckets, then 4-hour buckets.
//!
//! ```bash
//! make artifacts && cargo run --release --offline --example trace_analysis
//! ```

use anyhow::Result;

use cloudcoaster::coordinator::report::artifacts_dir;
use cloudcoaster::metrics::TimeSeries;
use cloudcoaster::runtime::AnalyticsEngine;
use cloudcoaster::sim::Rng;
use cloudcoaster::trace::synth::{google_like, GoogleLikeParams};
use cloudcoaster::trace::TraceStats;

fn main() -> Result<()> {
    let params = GoogleLikeParams::default();
    let workload = google_like(&params, &mut Rng::new(23));
    println!("trace: {}", TraceStats::of(&workload).summary());

    // Theoretical schedule: unlimited cluster + omniscient scheduler means
    // every task runs [arrival, arrival + duration).
    let mut starts = Vec::new();
    let mut ends = Vec::new();
    for job in &workload.jobs {
        for &d in &job.task_durations {
            starts.push(job.arrival as f32);
            ends.push((job.arrival + d) as f32);
        }
    }

    // 100-second sample points over the horizon, streamed through the
    // fixed-shape kernel in windows of BUCKETS points.
    let mut analytics = AnalyticsEngine::auto(&artifacts_dir());
    let engine_name = analytics.as_dyn().name();
    let horizon = params.horizon;
    let n_points = (horizon / 100.0) as usize;
    let mut fine = TimeSeries::new();
    let window = cloudcoaster::runtime::artifacts::BUCKETS;
    let mut kernel_ms = 0.0;
    for chunk_start in (0..n_points).step_by(window) {
        let points: Vec<f32> = (chunk_start..(chunk_start + window).min(n_points))
            .map(|i| (i as f32) * 100.0)
            .collect();
        let t0 = std::time::Instant::now();
        let counts = analytics.as_dyn().concurrency(&starts, &ends, &points)?;
        kernel_ms += t0.elapsed().as_secs_f64() * 1000.0;
        for (p, c) in points.iter().zip(&counts) {
            fine.push(*p as f64, *c as f64);
        }
    }

    // Paper's smoothing: 100 s averages -> 4 h averages.
    let coarse = fine.rebucket(4.0 * 3600.0);
    let mean = fine.mean();
    let std = {
        let m = mean;
        let pts: Vec<f64> = fine.points.iter().map(|&(_, v)| v).collect();
        (pts.iter().map(|v| (v - m) * (v - m)).sum::<f64>() / pts.len() as f64).sqrt()
    };
    println!("\nFigure 1 series (4-hour averages of concurrent tasks):");
    println!("{:>10} {:>12}", "hour", "tasks");
    for &(t, v) in &coarse.points {
        let bars = (v / coarse.max() * 50.0) as usize;
        println!("{:>10.1} {:>12.0} {}", t / 3600.0, v, "#".repeat(bars));
    }
    let peak = coarse.max();
    let trough = coarse
        .points
        .iter()
        .map(|&(_, v)| v)
        .fold(f64::INFINITY, f64::min);
    println!("\nmean {mean:.0} ± {std:.0} concurrent tasks (red dashed lines in the paper)");
    println!(
        "peak/trough over 4h averages: {:.1}X (paper: >6X) [analytics: {engine_name}, \
         kernel time {kernel_ms:.0} ms for {} tasks x {n_points} sample points]",
        peak / trough.max(1.0),
        starts.len()
    );
    Ok(())
}
