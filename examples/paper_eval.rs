//! **End-to-end paper evaluation driver** — regenerates every table and
//! figure in the paper's §4 on the full-scale configuration (4000
//! servers, N_s = 80, p = 0.5, L_r^T = 0.95, 120 s provisioning delay,
//! 24 h Yahoo-like trace):
//!
//! * Figure 3 — CDFs of short-task queueing delay (baseline + r = 1,2,3),
//!   computed through the AOT-compiled delay-histogram kernel.
//! * Table 1  — transient lifetimes and active counts.
//! * Headline — avg/max delay improvement and short-partition cost saving.
//!
//! Results land in `results/` as CSV + markdown. Recorded in
//! EXPERIMENTS.md.
//!
//! ```bash
//! make artifacts && cargo run --release --offline --example paper_eval
//! # or a specific experiment:
//! cargo run --release --offline --example paper_eval -- fig3
//! ```

use anyhow::Result;

use cloudcoaster::coordinator::config::ExperimentConfig;
use cloudcoaster::coordinator::report::{
    fig3_cdf_csv, fig3_markdown, summary_line, table1_markdown, workload_summary,
};
use cloudcoaster::coordinator::sweep::{paper_points, run_sweep_parallel};

fn main() -> Result<()> {
    let what = std::env::args().nth(1).unwrap_or_else(|| "all".to_string());
    let cfg = ExperimentConfig::paper_defaults();
    println!("configuration: 4000 servers, N_s=80, p=0.5, L_r^T=0.95, 120s provisioning");
    println!("workload: {}", workload_summary(&cfg)?);

    let wall = std::time::Instant::now();
    let threads = cloudcoaster::coordinator::sweep::default_threads();
    let reports = run_sweep_parallel(&cfg, &paper_points(&cfg, &[1.0, 2.0, 3.0]), threads)?;
    println!(
        "\n4 simulations in {:.1}s on {threads} threads:",
        wall.elapsed().as_secs_f64()
    );
    for rep in &reports {
        println!("  {}", summary_line(rep));
    }

    std::fs::create_dir_all("results")?;
    if what == "all" || what == "fig3" {
        println!("\n== Figure 3: CDFs of short-task queueing delay ==");
        println!("{}", fig3_markdown(&reports));
        std::fs::write("results/fig3_cdf.csv", fig3_cdf_csv(&reports))?;
        std::fs::write("results/fig3.md", fig3_markdown(&reports))?;
        println!("CDF series -> results/fig3_cdf.csv");
        // Render a terminal sketch of the CDFs at a few probe points.
        println!("\nCDF probe points (fraction of short tasks with delay <= t):");
        println!(
            "{:>18} {:>8} {:>8} {:>8} {:>8} {:>8}",
            "run", "10s", "60s", "300s", "1200s", "3600s"
        );
        for rep in &reports {
            let at = |x: f64| {
                let idx = rep.cdf.edges.partition_point(|&e| e <= x);
                rep.cdf.values[idx.saturating_sub(1).min(rep.cdf.values.len() - 1)]
            };
            println!(
                "{:>18} {:>8.3} {:>8.3} {:>8.3} {:>8.3} {:>8.3}",
                rep.name,
                at(10.0),
                at(60.0),
                at(300.0),
                at(1200.0),
                at(3600.0)
            );
        }
    }
    if what == "all" || what == "table1" {
        println!("\n== Table 1: transient server lifetimes and counts ==");
        println!("{}", table1_markdown(&reports));
        std::fs::write("results/table1.md", table1_markdown(&reports))?;
    }
    if what == "all" || what == "headline" {
        let base = &reports[0];
        let r3 = reports.iter().find(|r| r.scheduler == "cloudcoaster" && r.r == 3.0);
        if let Some(r3) = r3 {
            let mean_x = base.short_delay.mean / r3.short_delay.mean.max(1e-9);
            let max_x = base.short_delay.max / r3.short_delay.max.max(1e-9);
            let saving = (40.0 - r3.r_normalized_avg) / 40.0;
            println!("\n== Headline (paper: 4.8X avg, 1.83X max, 29.5% saving) ==");
            println!(
                "avg short queueing delay: {:.1}s -> {:.1}s = {mean_x:.2}X improvement",
                base.short_delay.mean, r3.short_delay.mean
            );
            println!(
                "max short queueing delay: {:.0}s -> {:.0}s = {max_x:.2}X improvement",
                base.short_delay.max, r3.short_delay.max
            );
            println!(
                "long-job delay maintained: {:.0}s (baseline) vs {:.0}s (r=3)",
                base.long_delay.mean, r3.long_delay.mean
            );
            println!(
                "short-partition cost: {:.1} r-normalized on-demand equivalents vs 40 \
                 static = {:.1}% saving",
                r3.r_normalized_avg,
                100.0 * saving
            );
        }
    }
    Ok(())
}
