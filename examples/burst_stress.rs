//! Flash-crowd stress scenario: a hand-built workload with one extreme
//! long-job burst, showing the transient manager's adaptation timeline —
//! the l_r trajectory, the transient fleet ramp, the provisioning lag,
//! and the graceful drain afterwards.
//!
//! ```bash
//! cargo run --release --offline --example burst_stress
//! ```

use anyhow::Result;

use cloudcoaster::cluster::QueuePolicy;
use cloudcoaster::coordinator::runner::{simulate, SimConfig};
use cloudcoaster::sched::Hybrid;
use cloudcoaster::sim::Rng;
use cloudcoaster::trace::{Job, Workload};
use cloudcoaster::transient::{Budget, ManagerConfig};
use cloudcoaster::util::JobId;

fn main() -> Result<()> {
    // 400-server cluster, 16-server short partition (p=0.5 -> 8 on-demand
    // + up to 24 transients at r=3).
    let n_servers = 400;
    let n_short = 16;
    let mut rng = Rng::new(7);
    let mut jobs: Vec<Job> = Vec::new();

    // Steady short-job stream over 4 hours.
    let horizon = 4.0 * 3600.0;
    let mut t = 0.0;
    while t < horizon {
        t += rng.exponential(4.0);
        let n = 1 + rng.below(8) as usize;
        let durs = (0..n).map(|_| rng.lognormal(3.0, 0.5)).collect();
        jobs.push(Job { id: JobId(0), arrival: t, task_durations: durs, is_long: false });
    }
    // The flash crowd: at t=1h, a burst of long jobs saturates the
    // general partition within minutes.
    for i in 0..40 {
        let durs = (0..12).map(|_| rng.lognormal(7.2, 0.4)).collect();
        jobs.push(Job {
            id: JobId(0),
            arrival: 3600.0 + i as f64 * 10.0,
            task_durations: durs,
            is_long: true,
        });
    }
    let workload = Workload::new(jobs, 90.0);

    let cfg = SimConfig {
        n_general: n_servers - n_short,
        n_short_reserved: n_short / 2,
        queue_policy: QueuePolicy::Srpt { starvation_limit: 600.0 },
        manager: Some(ManagerConfig::paper(Budget::new(n_short, 0.5, 3.0))),
        snapshot_interval: 60.0,
        steal_probes: 8,
        steal_batch: 8,
        seed: 7,
    };
    let mut sched = Hybrid::cloudcoaster(2.0);
    let res = simulate(&workload, &mut sched, &cfg);

    println!("flash-crowd adaptation timeline (one row per 5 min):");
    println!("{:>8} {:>8} {:>12}  fleet", "min", "l_r", "transients");
    for (i, &(t, lr)) in res.rec.lr_series.points.iter().enumerate() {
        if i % 5 != 0 {
            continue;
        }
        let transients = res.rec.transient_series.points[i].1;
        let bars = "#".repeat(transients as usize);
        println!("{:>8.0} {:>8.3} {:>12.0}  {bars}", t / 60.0, lr, transients);
    }
    let (adds, drains, _) = res.manager_stats.unwrap();
    println!(
        "\n{} transients requested, {} drained; short delay mean {:.1}s p99 {:.1}s; \
         {} stale copies skipped; {:.0}k events/s",
        adds,
        drains,
        res.rec.short_delays.mean(),
        {
            let mut d = res.rec.short_delays.clone();
            d.percentile(0.99)
        },
        res.rec.stale_copies_skipped,
        res.events_per_sec() / 1000.0,
    );
    Ok(())
}
