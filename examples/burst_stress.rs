//! Flash-crowd stress scenario, composed from streaming combinators: a
//! steady short-job stream [`Merge`]d with a hand-built long-job flash
//! crowd, then intensified with a [`BurstStorm`] window — showing the
//! transient manager's adaptation timeline (the l_r trajectory, the
//! transient fleet ramp, the provisioning lag, and the graceful drain
//! afterwards) and the streaming core's bounded memory.
//!
//! ```bash
//! cargo run --release --offline --example burst_stress
//! ```

use anyhow::Result;

use cloudcoaster::cluster::QueuePolicy;
use cloudcoaster::coordinator::runner::{simulate_source, SimConfig};
use cloudcoaster::sched::Hybrid;
use cloudcoaster::sim::Rng;
use cloudcoaster::trace::{BurstStorm, Job, Merge, VecSource};
use cloudcoaster::transient::{Budget, ManagerConfig};
use cloudcoaster::util::JobId;

fn main() -> Result<()> {
    // 400-server cluster, 16-server short partition (p=0.5 -> 8 on-demand
    // + up to 24 transients at r=3).
    let n_servers = 400;
    let n_short = 16;
    let mut rng = Rng::new(7);

    // Steady short-job stream over 4 hours.
    let horizon = 4.0 * 3600.0;
    let mut shorts: Vec<Job> = Vec::new();
    let mut t = 0.0;
    while t < horizon {
        t += rng.exponential(4.0);
        let n = 1 + rng.below(8) as usize;
        let durs = (0..n).map(|_| rng.lognormal(3.0, 0.5)).collect();
        shorts.push(Job { id: JobId(0), arrival: t, task_durations: durs, is_long: false });
    }
    // The flash crowd: at t=1h, a burst of long jobs saturates the
    // general partition within minutes.
    let longs: Vec<Job> = (0..20)
        .map(|i| {
            let durs = (0..12).map(|_| rng.lognormal(7.2, 0.4)).collect();
            Job {
                id: JobId(0),
                arrival: 3600.0 + i as f64 * 10.0,
                task_durations: durs,
                is_long: true,
            }
        })
        .collect();

    // Combinator pipeline: merge the streams, then double the arrival
    // rate inside the crowd window — 40 long jobs land without ever
    // materialising a combined trace.
    let source = BurstStorm::new(
        Box::new(Merge::new(
            Box::new(VecSource::new(shorts, 90.0)),
            Box::new(VecSource::new(longs, 90.0)),
        )),
        vec![(3600.0, 3800.0)],
        2.0,
    );

    let cfg = SimConfig {
        n_general: n_servers - n_short,
        n_short_reserved: n_short / 2,
        queue_policy: QueuePolicy::Srpt { starvation_limit: 600.0 },
        manager: Some(ManagerConfig::paper(Budget::new(n_short, 0.5, 3.0))),
        snapshot_interval: 60.0,
        steal_probes: 8,
        steal_batch: 8,
        recycle_task_slots: true,
        recycle_server_slots: true,
        exact_delay_samples: false,
        exact_snapshot_series: false,
        seed: 7,
    };
    let mut sched = Hybrid::cloudcoaster(2.0);
    let res = simulate_source(Box::new(source), &mut sched, &cfg, None);

    println!("flash-crowd adaptation timeline (one row per 5 min):");
    println!("{:>8} {:>8} {:>12}  fleet", "min", "l_r", "transients");
    for (i, &(t, lr)) in res.rec.lr_series.points.iter().enumerate() {
        if i % 5 != 0 {
            continue;
        }
        let transients = res.rec.transient_series.points[i].1;
        let bars = "#".repeat(transients as usize);
        println!("{:>8.0} {:>8.3} {:>12.0}  {bars}", t / 60.0, lr, transients);
    }
    let (adds, drains, _) = res.manager_stats.unwrap();
    println!(
        "\n{} transients requested, {} drained; short delay mean {:.1}s p99 {:.1}s; \
         {} stale copies skipped; peak {} resident jobs / {} task slots / {} server slots; \
         {:.0}k events/s",
        adds,
        drains,
        res.rec.short_delays.mean(),
        {
            let mut d = res.rec.short_delays.clone();
            d.percentile(0.99)
        },
        res.rec.stale_copies_skipped,
        res.peak_resident_jobs,
        res.peak_resident_tasks,
        res.peak_resident_servers,
        res.events_per_sec() / 1000.0,
    );
    Ok(())
}
