//! Two-cluster federation under staggered burst storms with one pooled
//! transient budget — the cross-cluster elasticity experiment: cluster
//! 0's storm passes before cluster 1's begins, so the pooled budget
//! serves both bursts with the transient fleet one statically-sliced
//! budget would split in half.
//!
//! ```bash
//! cargo run --release --offline --example federated_burst
//! ```

use anyhow::Result;

use cloudcoaster::coordinator::config::{ExperimentConfig, SchedulerKind, WorkloadSource};
use cloudcoaster::coordinator::report::{run_federated_experiment, summary_line};
use cloudcoaster::coordinator::scenario::{
    named, BudgetSharing, FederationSpec, RouterKind,
};
use cloudcoaster::trace::synth::YahooLikeParams;

fn run_with(sharing: BudgetSharing) -> Result<cloudcoaster::coordinator::FederatedReport> {
    // A small CloudCoaster experiment: 120 servers per cluster, 8-server
    // short partition (p = 0.5, r = 3 -> pooled K = 12 transients).
    let mut cfg = ExperimentConfig::paper_defaults();
    cfg.scheduler = SchedulerKind::CloudCoaster;
    cfg.cluster_size = 120;
    cfg.short_partition = 8;
    cfg.threshold = 0.5;
    cfg.seed = 7;
    let mut p = YahooLikeParams::default();
    p.horizon = 4.0 * 3600.0;
    cfg.workload = WorkloadSource::YahooLike(p);

    // The registry's burst-storm base (one window at 25%..40% of the
    // horizon); the federation staggers it per cluster.
    cfg.scenario = Some(named("burst-storm", &cfg)?);
    cfg.federation = Some(FederationSpec {
        clusters: 2,
        router: RouterKind::PassThrough,
        budget_sharing: sharing,
        // Cluster 1's storm starts ~35 min after cluster 0's ends.
        stagger: 0.35 * 4.0 * 3600.0,
    });
    run_federated_experiment(&cfg)
}

fn main() -> Result<()> {
    for sharing in [BudgetSharing::Pooled, BudgetSharing::Split] {
        let fed = run_with(sharing)?;
        println!("== budget sharing: {:?} ==", sharing);
        for (i, rep) in fed.per_cluster.iter().enumerate() {
            println!("  cluster {i}: {}", summary_line(rep));
        }
        println!("  aggregate: {}", summary_line(&fed.aggregate));
        println!(
            "  transient peak across clusters: {} (cap {:?}) — \
             requested {}, mean lifetime {:.2} h",
            fed.peak_total_fleet,
            fed.shared_cap,
            fed.aggregate.transients_requested,
            fed.aggregate.mean_lifetime_h,
        );
        println!(
            "  short delays: mean {:.1}s p99 {:.1}s over {} tasks\n",
            fed.aggregate.short_delay.mean,
            fed.aggregate.short_delay.p99,
            fed.aggregate.short_delay.n,
        );
    }
    println!(
        "staggered storms mean the pooled run can lease up to the full K \
         during each cluster's burst, while the split run caps each \
         cluster at K/2 — compare the per-cluster p99s above."
    );
    Ok(())
}
