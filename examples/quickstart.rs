//! Quickstart: run CloudCoaster vs. the Eagle baseline on a small
//! synthetic cluster and print the headline numbers.
//!
//! ```bash
//! cargo run --release --offline --example quickstart
//! ```

use anyhow::Result;

use cloudcoaster::cluster::Cluster;
use cloudcoaster::coordinator::config::{ExperimentConfig, SchedulerKind, WorkloadSource};
use cloudcoaster::coordinator::report::{build_workload, run_experiment_on, summary_line};
use cloudcoaster::metrics::Recorder;
use cloudcoaster::runtime::AnalyticsEngine;
use cloudcoaster::sched::Hybrid;
use cloudcoaster::sim::{SchedulerComponent, SnapshotSampler, World};
use cloudcoaster::trace::synth::YahooLikeParams;
use cloudcoaster::trace::TraceStats;

fn main() -> Result<()> {
    // A 500-server cluster with a 2-hour Yahoo-like workload: small
    // enough to run in about a second, big enough to show the effect.
    let mut cfg = ExperimentConfig::paper_defaults();
    cfg.cluster_size = 500;
    cfg.short_partition = 16;
    let mut params = YahooLikeParams::default();
    params.horizon = 4.0 * 3600.0;
    // Scale the workload to the smaller cluster (~1/8th of paper scale):
    // rates shrink with the cluster, dwell times shrink with the horizon
    // so the high/low occupancy phases still alternate within the run.
    params.short_arrivals.calm_rate /= 8.0;
    params.short_arrivals.burst_rate /= 8.0;
    // Longs scale less than the cluster so the general partition still
    // saturates (the quickstart exists to show the crowded regime).
    params.long_arrivals.calm_rate /= 4.0;
    params.long_arrivals.burst_rate /= 4.0;
    params.long_arrivals.calm_dwell /= 6.0;
    params.long_arrivals.burst_dwell /= 6.0;
    cfg.workload = WorkloadSource::YahooLike(params);

    let workload = build_workload(&cfg)?;
    println!("workload: {}", TraceStats::of(&workload).summary());

    // Analytics: XLA artifacts if built (make artifacts), else native.
    let mut analytics =
        AnalyticsEngine::auto(&cloudcoaster::coordinator::report::artifacts_dir());

    let mut baseline_cfg = cfg.clone();
    baseline_cfg.scheduler = SchedulerKind::Eagle;
    let baseline = run_experiment_on(&baseline_cfg, &workload, analytics.as_dyn())?;
    println!("{}", summary_line(&baseline));

    let cc = run_experiment_on(&cfg, &workload, analytics.as_dyn())?;
    println!("{}", summary_line(&cc));

    let speedup = baseline.short_delay.mean / cc.short_delay.mean.max(1e-9);
    println!(
        "\nCloudCoaster (r={}) improves average short-task queueing delay by {:.1}x \
         ({:.1}s -> {:.1}s) using on average {:.1} transient servers \
         ({:.1} on-demand-equivalents vs {} in the static baseline partition).",
        cfg.r,
        speedup,
        baseline.short_delay.mean,
        cc.short_delay.mean,
        cc.avg_transients,
        cc.r_normalized_avg,
        cfg.short_partition / 2,
    );

    // Custom-scenario composition: the same simulation as a `World` with
    // hand-picked components — here an Eagle run with *no* work stealer
    // wired in, something that used to require a runner code change. The
    // world streams its arrivals from the eager workload built above
    // (`World::from_workload`); a lazy source works identically.
    let sim_cfg = baseline_cfg.to_sim_config();
    let mut sched = Hybrid::eagle(2.0);
    let cluster = Cluster::new(sim_cfg.n_general, sim_cfg.n_short_reserved, sim_cfg.queue_policy);
    let mut world = World::from_workload(&workload, cluster, Recorder::new(1.0), sim_cfg.seed);
    world.add_component(Box::new(SnapshotSampler::new(sim_cfg.snapshot_interval)));
    world.add_component(Box::new(SchedulerComponent::new(&mut sched)));
    world.run();
    println!(
        "\ncustom world (eagle, stealing disabled): {} tasks in {} events, \
         mean short delay {:.1}s (vs {:.1}s with stealing)",
        world.rec.tasks_finished,
        world.engine.processed(),
        world.rec.short_delays.mean(),
        baseline.short_delay.mean,
    );

    // Declarative scenarios: the same workload with a 3x burst storm
    // injected mid-run and the transient manager removed, straight from
    // a `[scenario]` TOML block (the CLI equivalent is
    // `cloudcoaster run --config FILE` or `--scenario burst-storm`).
    // The scenario pipeline streams: peak resident jobs stay bounded by
    // cluster load no matter how long the trace is.
    let scenario_toml = r#"
        [cluster]
        servers = 500
        short_partition = 16

        [scenario]
        name = "storm-managerless"
        storm_windows = [3600, 5400]   # one storm hour into the run
        storm_intensity = 3.0          # 3x arrival rate in-window
        manager = "none"               # scheduler only, no TransientManager
    "#;
    let mut storm_cfg = ExperimentConfig::from_toml(scenario_toml)?;
    storm_cfg.workload = cfg.workload.clone(); // same synthetic trace params
    let storm = run_experiment_on(&storm_cfg, &workload, analytics.as_dyn())?;
    println!("\n[scenario] {}", summary_line(&storm));
    println!(
        "storm scenario streamed {} tasks with at most {} jobs / {} task slots / \
         {} server slots resident ({} bytes of delay sketches)",
        storm.short_delay.n + storm.long_delay.n,
        storm.peak_resident_jobs,
        storm.peak_resident_tasks,
        storm.peak_resident_servers,
        storm.delay_struct_bytes,
    );
    Ok(())
}
